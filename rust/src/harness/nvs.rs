//! Table 5 (+ Appendix Tables 8/9/10) — NVS: PSNR/SSIM/LPIPS per scene and
//! variant measured through the Rust renderer, plus Eyeriss latency/energy
//! for a full render at GNT's true shapes.

use anyhow::Result;

use crate::energy::area::AreaModel;
use crate::energy::eyeriss::{energy, Hierarchy};
use crate::model::config::{gnt, nerf};
use crate::model::ops::{count, Variant};
use crate::nvs::render::eval_scene;
use crate::nvs::scenes::Scene;
use crate::runtime::engine::Engine;
use crate::util::bench::{f2, Table};

/// The NVS variant ladder of Table 5 (artifact name, display label, variant
/// for op counting).
pub const NVS_LADDER: [(&str, &str, Variant); 4] = [
    ("nvs_gnt_r256", "GNT", Variant::MSA),
    ("nvs_add_r256", "ShiftAddViT (Add)", Variant::ADD),
    (
        "nvs_add_shift_both_r256",
        "ShiftAddViT (Add+Shift Both)",
        Variant::ADD_SHIFT_BOTH,
    ),
    (
        "nvs_add_shiftattn_moe_r256",
        "ShiftAddViT (Shift Attn + MoE MLP)",
        Variant::SHIFTADD_MOE,
    ),
];

/// Rays per rendered image at the paper's LLFF resolution (1008×756).
const PAPER_RAYS: f64 = 1008.0 * 756.0;

/// Table 5 quality metrics for `scenes` at render size `img`.
pub fn table5_quality(engine: &Engine, scenes: &[&str], img: usize) -> Result<()> {
    let mut t = Table::new(&["Scene", "Variant", "PSNR", "SSIM", "LPIPS*"]);
    for scene_name in scenes {
        let scene = Scene::from_manifest(&engine.manifest().root, scene_name)?;
        for (artifact, label, _) in NVS_LADDER {
            match eval_scene(engine, &scene, artifact, img, 0.15) {
                Ok(e) => t.row(&[
                    scene_name.to_string(),
                    label.to_string(),
                    f2(e.psnr),
                    format!("{:.3}", e.ssim),
                    format!("{:.3}", e.lpips),
                ]),
                Err(_) => t.row(&[
                    scene_name.to_string(),
                    label.to_string(),
                    "n/a".into(),
                    "n/a".into(),
                    "n/a".into(),
                ]),
            }
        }
    }
    t.print("Table 5/8/9/10 — NVS quality (synthetic light-field scenes; LPIPS* = gradient-structure proxy)");
    Ok(())
}

/// Table 5 latency/energy columns — Eyeriss model per full rendered frame at
/// the paper's true GNT/NeRF shapes.
pub fn table5_cost() {
    let h = Hierarchy::default();
    let a = AreaModel::default();
    let mut t = Table::new(&["Method", "Lat (s/frame)", "Energy (J/frame)"]);
    // NeRF baseline (MLP-only).
    let nerf_ops = count(&nerf(), Variant::MSA);
    t.row(&[
        "NeRF".to_string(),
        f2(a.latency_ms(&nerf_ops) * PAPER_RAYS / 1e3 / 192.0),
        f2(energy(&nerf_ops, &h).total_mj() * PAPER_RAYS / 1e6 / 192.0),
    ]);
    for (label, var) in [
        ("GNT", Variant::MSA),
        ("ShiftAddViT (Add)", Variant::ADD),
        ("ShiftAddViT (Add+Shift Both)", Variant::ADD_SHIFT_BOTH),
        ("ShiftAddViT (Shift Attn + MoE)", Variant::SHIFTADD_MOE),
    ] {
        // ops are per token-set of one ray (192 points); scale to all rays
        let ops = count(&gnt(), var);
        t.row(&[
            label.to_string(),
            f2(a.latency_ms(&ops) * PAPER_RAYS / 1e3 / 192.0),
            f2(energy(&ops, &h).total_mj() * PAPER_RAYS / 1e6 / 192.0),
        ]);
    }
    t.print("Table 5 — per-frame latency/energy (Eyeriss model, GNT true shapes, 1008x756 frame)");
}
