//! Infrastructure substrates built in-repo (the sandbox vendors only the
//! `xla` crate's dependency closure — no tokio/clap/serde/criterion/proptest;
//! see DESIGN.md §6).

pub mod bench;
pub mod cli;
pub mod httpd;
pub mod image;
pub mod json;
pub mod log;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
