//! PPM image writer + ASCII heatmaps — used by the Fig. 6/9 token-dispatch
//! visualisation and the Fig. 10 qualitative NVS renders.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// Write an RGB float image (values in [0,1], row-major, HWC) as binary PPM.
pub fn write_ppm(path: &Path, rgb: &[f32], w: usize, h: usize) -> Result<()> {
    assert_eq!(rgb.len(), w * h * 3, "rgb buffer size mismatch");
    let mut f = std::fs::File::create(path)?;
    write!(f, "P6\n{w} {h}\n255\n")?;
    let bytes: Vec<u8> = rgb
        .iter()
        .map(|v| (v.clamp(0.0, 1.0) * 255.0).round() as u8)
        .collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Render a boolean token grid (e.g. Mult-vs-Shift dispatch) as ASCII.
/// `true` = Mult expert (█), `false` = Shift expert (·) — Fig. 6's
/// yellow/blue convention.
pub fn ascii_grid(mask: &[bool], grid: usize) -> String {
    let mut out = String::new();
    for y in 0..grid {
        for x in 0..grid {
            out.push(if mask[y * grid + x] { '█' } else { '·' });
        }
        out.push('\n');
    }
    out
}

/// Overlay a token mask on an image: Mult tokens keep their color, Shift
/// tokens are dimmed — PPM version of Fig. 6.
pub fn overlay_dispatch(
    img: &[f32],
    w: usize,
    h: usize,
    mask: &[bool],
    grid: usize,
) -> Vec<f32> {
    let patch = w / grid;
    let mut out = img.to_vec();
    for y in 0..h {
        for x in 0..w {
            let token = (y / patch).min(grid - 1) * grid + (x / patch).min(grid - 1);
            if !mask[token] {
                for c in 0..3 {
                    out[(y * w + x) * 3 + c] *= 0.25;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppm_roundtrip_header() {
        let dir = std::env::temp_dir().join("savit_img_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ppm");
        let img = vec![0.5f32; 4 * 4 * 3];
        write_ppm(&p, &img, 4, 4).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(data.starts_with(b"P6\n4 4\n255\n"));
        assert_eq!(data.len(), b"P6\n4 4\n255\n".len() + 48);
    }

    #[test]
    fn ascii_grid_shape() {
        let g = ascii_grid(&[true, false, false, true], 2);
        assert_eq!(g.lines().count(), 2);
        assert!(g.contains('█') && g.contains('·'));
    }

    #[test]
    fn overlay_dims_shift_tokens() {
        let img = vec![1.0f32; 8 * 8 * 3];
        let mask = vec![false; 4]; // all Shift → all dimmed
        let out = overlay_dispatch(&img, 8, 8, &mask, 2);
        assert!(out.iter().all(|v| (*v - 0.25).abs() < 1e-6));
    }
}
