//! Minimal CLI flag parser (clap is unavailable offline; DESIGN.md §6).
//!
//! Grammar: `binary <subcommand> [--flag value] [--switch] [positional...]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--k=v`, `--k v`, or bare `--switch`.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = parse("serve --batch 8 --verbose --rate=100 input.txt");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("batch"), Some("8"));
        assert_eq!(a.get("rate"), Some("100"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 5 --r 2.5");
        assert_eq!(a.usize_or("n", 1).unwrap(), 5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!((a.f64_or("r", 0.0).unwrap() - 2.5).abs() < 1e-12);
        assert!(a.usize_or("r", 1).is_err());
    }

    #[test]
    fn no_subcommand_when_first_is_flag() {
        let a = parse("--x 1");
        assert!(a.subcommand.is_none());
        assert_eq!(a.get("x"), Some("1"));
    }

    #[test]
    fn trailing_switch() {
        let a = parse("run --fast");
        assert!(a.has("fast"));
        assert!(a.get("fast").is_none());
    }
}
