//! Latency/throughput statistics helpers (mean, percentiles, SCV).

/// Summary statistics over a set of samples (e.g. per-request latencies).
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// The zero-sample summary (`n = 0`, every statistic 0.0). Report
    /// builders use this so a run that completed nothing still reports
    /// instead of panicking at summary time.
    pub fn empty() -> Summary {
        Summary {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            p50: 0.0,
            p90: 0.0,
            p95: 0.0,
            p99: 0.0,
            max: 0.0,
        }
    }

    /// Total on any input: empty slices summarize to [`Summary::empty`],
    /// and NaN samples sort via `total_cmp` (they rank greatest) instead
    /// of panicking.
    pub fn from(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::empty();
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: percentile(&s, 0.50),
            p90: percentile(&s, 0.90),
            p95: percentile(&s, 0.95),
            p99: percentile(&s, 0.99),
            max: s[n - 1],
        }
    }
}

/// Percentile of a pre-sorted slice (nearest-rank with linear interpolation).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Squared coefficient of variation — the paper's SCV in Eq. (4).
pub fn scv(values: &[f64]) -> f64 {
    let n = values.len() as f64;
    let mean = values.iter().sum::<f64>() / n;
    let var = values.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    var / (mean * mean + 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::from(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 1.0, 2.0, 3.0];
        assert!((percentile(&s, 0.5) - 1.5).abs() < 1e-12);
        assert_eq!(percentile(&s, 0.0), 0.0);
        assert_eq!(percentile(&s, 1.0), 3.0);
    }

    #[test]
    fn scv_zero_for_balanced() {
        assert!(scv(&[2.0, 2.0, 2.0]) < 1e-12);
    }

    #[test]
    fn scv_grows_with_imbalance() {
        let balanced = scv(&[1.0, 1.0]);
        let skewed = scv(&[1.9, 0.1]);
        assert!(skewed > balanced + 0.5);
    }

    #[test]
    fn summary_of_empty_is_zeroed_not_a_panic() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.p99, 0.0);
        assert_eq!(s.max, 0.0);
    }

    #[test]
    fn summary_tolerates_nan_samples() {
        // total_cmp ranks NaN greatest, so min/p50 stay meaningful and
        // nothing panics.
        let s = Summary::from(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert!(s.max.is_nan());
    }

    #[test]
    fn summary_orders_percentiles() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64) * 0.1).collect();
        let s = Summary::from(&xs);
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p95);
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
        // uniform 0..99.9: p95 sits at ~94.9
        assert!((s.p95 - 94.905).abs() < 1e-9);
    }
}
