//! Worker-thread pool with persistent workers (tokio is unavailable offline;
//! DESIGN.md §6). Used for expert-parallel MoE dispatch and the serving loop.
//!
//! Design: N persistent threads pulling boxed jobs from a shared queue
//! (`Mutex<VecDeque>` + `Condvar`). Jobs signal completion through the
//! returned [`JoinHandle`]'s channel. No allocation is amortized away — but
//! workers are persistent, so the hot path never spawns threads (the paper's
//! "experts run concurrently" requirement).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    ready: Condvar,
    shutdown: AtomicBool,
}

/// Fixed-size persistent worker pool.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

/// Handle to a submitted job's result.
pub struct JoinHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> JoinHandle<T> {
    /// Block until the job finishes.
    pub fn join(self) -> T {
        self.rx.recv().expect("worker dropped result")
    }
}

impl Pool {
    pub fn new(n: usize) -> Pool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..n.max(1))
            .map(|i| {
                let sh = shared.clone();
                thread::Builder::new()
                    .name(format!("savit-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Pool { shared, workers }
    }

    /// Submit a job; returns a handle to its result.
    pub fn submit<T, F>(&self, f: F) -> JoinHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let job: Job = Box::new(move || {
            let _ = tx.send(f());
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(job);
        }
        self.shared.ready.notify_one();
        JoinHandle { rx }
    }

    /// Run all closures concurrently and collect results in order.
    pub fn scatter<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let handles: Vec<_> = jobs.into_iter().map(|f| self.submit(f)).collect();
        handles.into_iter().map(|h| h.join()).collect()
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if sh.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = sh.ready.wait(q).unwrap();
            }
        };
        job();
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn submit_returns_result() {
        let pool = Pool::new(2);
        let h = pool.submit(|| 21 * 2);
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn scatter_preserves_order() {
        let pool = Pool::new(4);
        let jobs: Vec<_> = (0..16)
            .map(|i| move || i * i)
            .collect();
        let out = pool.scatter(jobs);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let pool = Pool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..50)
            .map(|_| {
                let c = counter.clone();
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.scatter(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = Pool::new(2);
        let _ = pool.submit(|| 1).join();
        drop(pool); // must not hang
    }
}
