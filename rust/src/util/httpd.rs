//! Minimal HTTP/1.1 plumbing on `std::net` (hyper/axum are unavailable
//! offline; DESIGN.md §6). Three pieces:
//!
//! - [`read_request`] — a bounded request parser over any `BufRead`
//!   (request line, headers, `Content-Length` body);
//! - [`write_response`] / [`ChunkedWriter`] — response writers for fixed
//!   bodies and `Transfer-Encoding: chunked` streams;
//! - [`request`] — a tiny blocking client, so integration tests and the
//!   CI smoke exercise the real socket path without curl.
//!
//! Deliberately small: one request per connection (`Connection: close`),
//! no chunked *request* bodies, no TLS. Every parse error is a caller-side
//! problem — the front door maps them to 400s.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

/// Request line + headers may not exceed this (slowloris/garbage guard).
pub const MAX_HEAD_BYTES: usize = 64 * 1024;

/// Largest request body accepted (a classify body is ~100 KB of JSON).
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    /// path without the query string
    pub path: String,
    pub query: Option<String>,
    /// header names lowercased
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn body_text(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|_| anyhow!("request body is not UTF-8"))
    }
}

/// Read one line terminated by `\n`, stripping `\r\n`. `Ok(None)` = clean
/// EOF before any byte; EOF mid-line is an error.
fn read_line(r: &mut impl BufRead, budget: &mut usize) -> Result<Option<String>> {
    let mut buf = Vec::new();
    // Bound the read itself, not just the post-hoc budget check: a peer
    // streaming an endless line with no '\n' must error here instead of
    // growing `buf` without limit (remote memory-exhaustion guard).
    let n = r
        .take((*budget as u64).saturating_add(1))
        .read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    if *buf.last().unwrap() != b'\n' {
        if n > *budget {
            bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
        }
        bail!("truncated line (connection closed mid-header)");
    }
    *budget = budget
        .checked_sub(n)
        .ok_or_else(|| anyhow!("request head exceeds {MAX_HEAD_BYTES} bytes"))?;
    buf.pop();
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map(Some).map_err(|_| anyhow!("header line is not UTF-8"))
}

/// Parse one request off the stream. `Ok(None)` means the peer closed the
/// connection cleanly before sending anything (not an error). Any
/// malformed input is an `Err` the server maps to a 400.
pub fn read_request(r: &mut impl BufRead) -> Result<Option<HttpRequest>> {
    let mut budget = MAX_HEAD_BYTES;
    let line = match read_line(r, &mut budget)? {
        Some(l) => l,
        None => return Ok(None),
    };
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow!("empty request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| anyhow!("request line has no target: '{line}'"))?;
    let version = parts
        .next()
        .ok_or_else(|| anyhow!("request line has no HTTP version: '{line}'"))?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol '{version}'");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    let mut headers = Vec::new();
    loop {
        let line = read_line(r, &mut budget)?
            .ok_or_else(|| anyhow!("connection closed inside the header block"))?;
        if line.is_empty() {
            break;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed header line '{line}'"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let mut req = HttpRequest {
        method,
        path,
        query,
        headers,
        body: Vec::new(),
    };
    if let Some(te) = req.header("transfer-encoding") {
        bail!("transfer-encoding '{te}' request bodies are not supported (send Content-Length)");
    }
    if let Some(cl) = req.header("content-length") {
        let len: usize = cl
            .parse()
            .map_err(|_| anyhow!("bad Content-Length '{cl}'"))?;
        if len > MAX_BODY_BYTES {
            bail!("request body of {len} bytes exceeds the {MAX_BODY_BYTES} byte cap");
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)
            .map_err(|e| anyhow!("short request body ({e})"))?;
        req.body = body;
    }
    Ok(Some(req))
}

/// Canonical reason phrases for the statuses the front door emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write one complete `Connection: close` response with a fixed body.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        status_reason(status),
        body.len()
    )?;
    w.write_all(body)?;
    w.flush()
}

/// Writer for a `Transfer-Encoding: chunked` response — the `/stream`
/// endpoint emits one chunk per streaming event.
pub struct ChunkedWriter<'a, W: Write> {
    w: &'a mut W,
}

impl<'a, W: Write> ChunkedWriter<'a, W> {
    /// Send the status line + chunked headers; chunks follow.
    pub fn begin(w: &'a mut W, status: u16, content_type: &str) -> std::io::Result<Self> {
        write!(
            w,
            "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\ntransfer-encoding: chunked\r\nconnection: close\r\n\r\n",
            status_reason(status)
        )?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    /// Send one chunk (empty input is skipped — an empty chunk would
    /// terminate the stream).
    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Send the terminating zero-length chunk.
    pub fn finish(mut self) -> std::io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

/// A client-side response. `chunks` keeps per-chunk boundaries when the
/// server streamed (`body` is always the full concatenation).
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    /// header names lowercased
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    pub chunks: Vec<Vec<u8>>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn text(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|_| anyhow!("response body is not UTF-8"))
    }
}

/// Blocking one-shot HTTP client: connect, send, read the full response
/// (content-length, chunked, or to-EOF). Test/CI plumbing — serving never
/// calls this.
pub fn request(
    addr: impl ToSocketAddrs,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    timeout: Duration,
) -> Result<HttpResponse> {
    let addr = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow!("no address to connect to"))?;
    let stream = TcpStream::connect_timeout(&addr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut w = stream.try_clone()?;
    write!(w, "{method} {path} HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n")?;
    match body {
        Some(b) => {
            write!(w, "content-type: application/json\r\ncontent-length: {}\r\n\r\n", b.len())?;
            w.write_all(b)?;
        }
        None => write!(w, "\r\n")?,
    }
    w.flush()?;

    let mut r = BufReader::new(stream);
    let mut budget = MAX_HEAD_BYTES;
    let status_line = read_line(&mut r, &mut budget)?
        .ok_or_else(|| anyhow!("server closed before sending a status line"))?;
    let mut parts = status_line.split_whitespace();
    let version = parts
        .next()
        .ok_or_else(|| anyhow!("empty status line"))?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported response protocol '{version}'");
    }
    let status: u16 = parts
        .next()
        .ok_or_else(|| anyhow!("status line has no code: '{status_line}'"))?
        .parse()
        .map_err(|_| anyhow!("bad status code in '{status_line}'"))?;

    let mut headers = Vec::new();
    loop {
        let line = read_line(&mut r, &mut budget)?
            .ok_or_else(|| anyhow!("server closed inside the response headers"))?;
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }

    let mut resp = HttpResponse {
        status,
        headers,
        body: Vec::new(),
        chunks: Vec::new(),
    };
    let chunked = resp
        .header("transfer-encoding")
        .is_some_and(|v| v.eq_ignore_ascii_case("chunked"));
    if chunked {
        // chunk-size lines get their own budget — a long stream of events
        // is not an oversized head
        let mut chunk_budget = usize::MAX;
        loop {
            let size_line = read_line(&mut r, &mut chunk_budget)?
                .ok_or_else(|| anyhow!("server closed mid-chunk"))?;
            let size = usize::from_str_radix(size_line.trim(), 16)
                .map_err(|_| anyhow!("bad chunk size '{size_line}'"))?;
            let mut chunk = vec![0u8; size];
            r.read_exact(&mut chunk)?;
            let mut crlf = [0u8; 2];
            r.read_exact(&mut crlf)?;
            if size == 0 {
                break;
            }
            resp.body.extend_from_slice(&chunk);
            resp.chunks.push(chunk);
        }
    } else if let Some(cl) = resp.header("content-length") {
        let len: usize = cl
            .parse()
            .map_err(|_| anyhow!("bad response Content-Length '{cl}'"))?;
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        resp.body = body;
    } else {
        r.read_to_end(&mut resp.body)?;
    }
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::net::TcpListener;

    fn parse(text: &str) -> Result<Option<HttpRequest>> {
        read_request(&mut Cursor::new(text.as_bytes()))
    }

    #[test]
    fn parses_get_with_query_and_headers() {
        let req = parse("GET /metrics?pretty=1 HTTP/1.1\r\nHost: x\r\nX-Trace: 7\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/metrics");
        assert_eq!(req.query.as_deref(), Some("pretty=1"));
        assert_eq!(req.header("x-trace"), Some("7"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let req = parse("POST /classify HTTP/1.1\r\ncontent-length: 4\r\n\r\n{\"a\"")
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body_text().unwrap(), "{\"a\"");
    }

    #[test]
    fn clean_close_is_none_not_an_error() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn malformed_requests_error() {
        assert!(parse("GARBAGE\r\n\r\n").is_err(), "no target");
        assert!(parse("GET /x SPDY/3\r\n\r\n").is_err(), "bad protocol");
        assert!(parse("GET /x HTTP/1.1\r\nbroken header\r\n\r\n").is_err());
        assert!(
            parse("POST /x HTTP/1.1\r\ncontent-length: 99\r\n\r\nshort").is_err(),
            "short body"
        );
        assert!(
            parse("POST /x HTTP/1.1\r\ncontent-length: 999999999999\r\n\r\n").is_err(),
            "body cap"
        );
        assert!(
            parse("POST /x HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n").is_err(),
            "chunked request bodies unsupported"
        );
        assert!(parse("GET /half HTT").is_err(), "EOF mid-line");
    }

    #[test]
    fn oversized_head_is_rejected() {
        let huge = format!("GET /x HTTP/1.1\r\nh: {}\r\n\r\n", "y".repeat(MAX_HEAD_BYTES));
        assert!(parse(&huge).is_err());
    }

    #[test]
    fn endless_header_line_errors_without_buffering_unboundedly() {
        // A peer that streams forever without ever sending '\n' must hit
        // the head budget mid-read, not accumulate bytes until OOM.
        struct Endless;
        impl Read for Endless {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                buf.fill(b'a');
                Ok(buf.len())
            }
        }
        let err = read_request(&mut BufReader::new(Endless)).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "got: {err:#}");
    }

    #[test]
    fn response_writer_roundtrips_through_client_parser() {
        // Server side into a buffer...
        let mut buf = Vec::new();
        write_response(&mut buf, 200, "application/json", b"{\"ok\":true}").unwrap();
        // ...client side over a real socket echoing that buffer.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // drain the request, then replay the canned response
            let mut r = BufReader::new(s.try_clone().unwrap());
            let _ = read_request(&mut r).unwrap();
            s.write_all(&buf).unwrap();
        });
        let resp = request(addr, "GET", "/ok", None, Duration::from_secs(10)).unwrap();
        server.join().unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-type"), Some("application/json"));
        assert_eq!(resp.text().unwrap(), "{\"ok\":true}");
        assert!(resp.chunks.is_empty());
    }

    #[test]
    fn chunked_writer_roundtrips_with_chunk_boundaries() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let _ = read_request(&mut r).unwrap();
            let mut cw = ChunkedWriter::begin(&mut s, 200, "application/jsonl").unwrap();
            cw.chunk(b"{\"event\":\"progress\"}\n").unwrap();
            cw.chunk(b"").unwrap(); // skipped, must not terminate the stream
            cw.chunk(b"{\"event\":\"done\"}\n").unwrap();
            cw.finish().unwrap();
        });
        let resp = request(addr, "POST", "/stream", Some(b"{}"), Duration::from_secs(10)).unwrap();
        server.join().unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.chunks.len(), 2, "per-event chunk boundaries survive");
        assert_eq!(resp.chunks[0], b"{\"event\":\"progress\"}\n");
        assert_eq!(
            resp.text().unwrap(),
            "{\"event\":\"progress\"}\n{\"event\":\"done\"}\n"
        );
    }
}
