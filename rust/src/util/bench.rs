//! Tiny benchmark harness (criterion is unavailable offline; DESIGN.md §6).
//!
//! `cargo bench` drives `harness = false` binaries that call [`bench`] /
//! [`Table`] to print the paper's table rows with warmup + repeated timed
//! runs and mean/p50/p99.

use std::time::Instant;

use crate::util::stats::Summary;

/// Time `f` for `reps` iterations after `warmup` untimed ones.
/// Returns per-iteration latencies in milliseconds.
pub fn time_ms<F: FnMut()>(mut f: F, warmup: usize, reps: usize) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    out
}

/// One named measurement.
pub fn bench<F: FnMut()>(name: &str, f: F) -> Summary {
    let samples = time_ms(f, 3, 10);
    let s = Summary::from(&samples);
    println!(
        "{name:48}  mean {:8.3} ms  p50 {:8.3}  p99 {:8.3}",
        s.mean, s.p50, s.p99
    );
    s
}

/// Fixed-width table printer for reproducing the paper's tables.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            widths: headers.iter().map(|h| h.len().max(8)).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let line: Vec<String> = self
            .headers
            .iter()
            .zip(&self.widths)
            .map(|(h, w)| format!("{h:>w$}"))
            .collect();
        println!("{}", line.join("  "));
        println!("{}", "-".repeat(line.join("  ").len()));
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .zip(&self.widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            println!("{}", line.join("  "));
        }
    }
}

/// Format helper: `12.34` → "12.34", keeping tables compact.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ms_returns_reps_samples() {
        let samples = time_ms(
            || {
                std::hint::black_box(1 + 1);
            },
            1,
            5,
        );
        assert_eq!(samples.len(), 5);
        assert!(samples.iter().all(|s| *s >= 0.0));
    }

    #[test]
    fn table_accepts_rows_and_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["300000".into(), "4".into()]);
        t.print("test"); // should not panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
