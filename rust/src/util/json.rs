//! Minimal JSON parser/serializer (serde is unavailable offline; DESIGN.md §6).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`,
//! serving configs, and metrics dumps: objects, arrays, strings with escape
//! sequences, f64 numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are sorted (BTreeMap) for stable serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of usize (e.g. a shape).
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("expected number")))
            .collect()
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_num(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    // ---- serialization ----------------------------------------------------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact single-line serialization (`.to_string()` comes with it).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos..self.pos + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                b => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match b {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    s.push_str(std::str::from_utf8(
                        self.bytes
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("bad utf8"))?,
                    )?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let text = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": 2.5}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_f64(),
            Some(2.5)
        );
        // serialize → reparse → equal
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_negative_and_exponent() {
        let v = Json::parse("[-1.5e3, 2E-2, 0]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert!((a[1].as_f64().unwrap() - 0.02).abs() < 1e-12);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn usize_vec_helper() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn manifest_like_shape() {
        let text = r#"{"models": {"m1": {"path": "m1.hlo.txt", "inputs": [{"shape": [1, 32, 32, 3], "dtype": "float32"}]}}}"#;
        let v = Json::parse(text).unwrap();
        let m1 = v.get("models").unwrap().get("m1").unwrap();
        let shape = m1.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .usize_vec()
            .unwrap();
        assert_eq!(shape, vec![1, 32, 32, 3]);
    }
}
