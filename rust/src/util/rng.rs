//! Deterministic xorshift PRNGs.
//!
//! [`XorShift32`] is bit-identical to the Python generator in
//! `python/compile/data.py` so both sides draw the same synthetic datasets;
//! [`XorShift64`] is the general-purpose PRNG for benches/property tests.

/// 32-bit xorshift, mirrored in `python/compile/data.py::xorshift32`.
#[derive(Clone, Debug)]
pub struct XorShift32 {
    state: u32,
}

impl XorShift32 {
    pub fn new(seed: u32) -> Self {
        Self { state: seed | 1 }
    }

    pub fn next_u32(&mut self) -> u32 {
        let mut s = self.state;
        s ^= s << 13;
        s ^= s >> 17;
        s ^= s << 5;
        self.state = s;
        s
    }

    /// Uniform in [0, 1) with 24 bits of entropy (f32-exact; matches Python).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform integer in [lo, hi).
    pub fn randint(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.next_u32() % (hi - lo)
    }
}

/// 64-bit xorshift* for everything that does not need Python parity.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        Self {
            state: seed | 0x9E37_79B9_7F4A_7C15,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in [0,1).
    pub fn uniforms(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.uniform()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift32_matches_python_reference() {
        // First three draws for seed 1 (verified against data.py).
        let mut r = XorShift32::new(1);
        let a = r.next_u32();
        let b = r.next_u32();
        // Recompute by hand.
        let mut s: u32 = 1;
        s ^= s << 13;
        s ^= s >> 17;
        s ^= s << 5;
        assert_eq!(a, s);
        s ^= s << 13;
        s ^= s >> 17;
        s ^= s << 5;
        assert_eq!(b, s);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = XorShift32::new(42);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn randint_bounds() {
        let mut r = XorShift32::new(7);
        for _ in 0..1000 {
            let v = r.randint(3, 9);
            assert!((3..9).contains(&v));
        }
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = XorShift64::new(9);
        let xs = r.normals(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u64> = {
            let mut r = XorShift64::new(123);
            (0..10).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = XorShift64::new(123);
            (0..10).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}
