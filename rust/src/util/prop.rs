//! Randomized property-test harness (proptest is unavailable offline;
//! DESIGN.md §6).
//!
//! [`check`] runs a property over `cases` random inputs drawn by a generator
//! closure; on failure it *shrinks* by asking the generator for "smaller"
//! inputs (halved size parameter) until the property stops failing, then
//! panics with the smallest failing seed/size so the case is reproducible.

use crate::util::rng::XorShift64;

/// Run `prop(rng, size)` for `cases` random cases with sizes cycling up to
/// `max_size`. `prop` returns `Err(msg)` on violation.
pub fn check<F>(name: &str, cases: usize, max_size: usize, mut prop: F)
where
    F: FnMut(&mut XorShift64, usize) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let size = 1 + (case % max_size);
        let mut rng = XorShift64::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: halve the size until the property passes, keep the
            // smallest size that still fails.
            let mut failing = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = XorShift64::new(seed);
                match prop(&mut rng, s) {
                    Err(m) => {
                        failing = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, shrunk size={}): {}",
                failing.0, failing.1
            );
        }
    }
}

/// Assert two slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() / denom > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", 20, 8, |_rng, _size| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 20);
    }

    #[test]
    #[should_panic(expected = "property 'fails-big'")]
    fn failing_property_panics_with_shrunk_size() {
        check("fails-big", 20, 16, |_rng, size| {
            if size >= 4 {
                Err("too big".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_tolerates_small_error() {
        assert!(assert_close(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5).is_err());
    }
}
