//! Tiny leveled logger (`SHIFTADD_LOG=error|warn|info|debug|off`) — the
//! structured replacement for the ad-hoc `eprintln!` warnings that used to
//! live in the request queue, the planner's table pinning, and the fleet
//! supervisor.
//!
//! The level resolves lazily on first use: the environment variable wins;
//! otherwise the process default applies — [`Level::Off`] unless the
//! binary opted in via [`init_default`] (`main` sets `warn`), so library
//! consumers and the test suite stay silent by default.
//!
//! Use through the crate-root macros:
//! `crate::log_warn!("fleet: reaping worker {id}")` etc. Message
//! formatting is skipped entirely when the level is disabled.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    fn from_u8(v: u8) -> Level {
        match v {
            1 => Level::Error,
            2 => Level::Warn,
            3 => Level::Info,
            4 => Level::Debug,
            _ => Level::Off,
        }
    }

    fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "0" => Some(Level::Off),
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Sentinel meaning "not resolved yet".
const UNSET: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);
static DEFAULT: AtomicU8 = AtomicU8::new(Level::Off as u8);

fn resolve() -> Level {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != UNSET {
        return Level::from_u8(cur);
    }
    let l = std::env::var("SHIFTADD_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or_else(|| Level::from_u8(DEFAULT.load(Ordering::Relaxed)));
    LEVEL.store(l as u8, Ordering::Relaxed);
    l
}

/// Current level (resolving `SHIFTADD_LOG` on first call).
pub fn level() -> Level {
    resolve()
}

/// Force the level, overriding the environment (tests, tooling).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Set the level used when `SHIFTADD_LOG` is unset. Called by the binary's
/// entry point (`warn`); library/test use keeps the silent default. No-op
/// once the level has resolved.
pub fn init_default(l: Level) {
    DEFAULT.store(l as u8, Ordering::Relaxed);
}

/// Would a message at `l` be emitted?
pub fn enabled(l: Level) -> bool {
    l != Level::Off && l <= resolve()
}

/// Emit one line to stderr (macro backend — call via `log_warn!` etc.).
pub fn log(l: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{}] {}: {}", l.tag(), module, args);
    }
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_documented_levels() {
        assert_eq!(Level::parse("off"), Some(Level::Off));
        assert_eq!(Level::parse("ERROR"), Some(Level::Error));
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn levels_order_and_gate() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Off);
        assert!(!enabled(Level::Error));
    }
}
