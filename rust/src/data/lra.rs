//! Synthetic LRA-style sequence tasks (Table 11 substitute) — mirrors
//! `python/compile/model_lra.py::gen_task` semantics (not bit-exact; tasks
//! are evaluated python-side; the Rust side only needs request payloads for
//! latency benches, so any same-shape sequences suffice).

use crate::util::rng::XorShift64;

pub const VOCAB: usize = 16;
pub const TASKS: [&str; 4] = ["text", "listops", "retrieval", "image"];

/// A batch of token sequences for serving/bench traffic.
pub fn gen_sequences(seed: u64, n: usize, seq: usize) -> Vec<i32> {
    let mut rng = XorShift64::new(seed);
    (0..n * seq).map(|_| rng.range(0, VOCAB) as i32).collect()
}

/// Paper sequence lengths per task (Table 11 header).
pub fn paper_seq_len(task: &str) -> usize {
    match task {
        "text" => 4096,
        "listops" => 2048,
        "retrieval" => 4096,
        "image" => 1024,
        _ => panic!("unknown LRA task '{task}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let xs = gen_sequences(1, 4, 64);
        assert_eq!(xs.len(), 256);
        assert!(xs.iter().all(|t| (0..VOCAB as i32).contains(t)));
    }

    #[test]
    fn paper_lengths() {
        assert_eq!(paper_seq_len("text"), 4096);
        assert_eq!(paper_seq_len("image"), 1024);
    }
}
