//! Synthetic workload generators (dataset substitutes — DESIGN.md §2).

pub mod lra;
pub mod synth_images;
