//! Synthetic shapes dataset — bit-for-bit mirror of
//! `python/compile/data.py` (same xorshift32 stream, same integer
//! rasterizer), so the Rust serving path evaluates accuracy on exactly the
//! distribution the JAX models were trained on.

use crate::util::rng::XorShift32;

pub const IMG: usize = 32;
pub const NUM_CLASSES: usize = 8;

pub const SHAPE_NAMES: [&str; 8] = [
    "circle", "square", "triangle", "cross", "ring", "diamond", "hbar", "vbar",
];

/// Integer point-in-shape test (mirror of `data._inside`).
fn inside(shape_id: u32, dx: i32, dy: i32, r: i32) -> bool {
    let (ax, ay) = (dx.abs(), dy.abs());
    match shape_id {
        0 => dx * dx + dy * dy <= r * r,
        1 => ax <= r && ay <= r,
        2 => dy >= -r && dy <= r && ax * 2 <= (r - dy),
        3 => (ax <= r / 2 && ay <= r) || (ay <= r / 2 && ax <= r),
        4 => {
            let d2 = dx * dx + dy * dy;
            let inner = (r - 2).max(1);
            inner * inner <= d2 && d2 <= r * r
        }
        5 => ax + ay <= r,
        6 => ay <= (r / 3).max(1) && ax <= r,
        7 => ax <= (r / 3).max(1) && ay <= r,
        _ => unreachable!(),
    }
}

/// One generated sample: HWC float image in [0,1] + label + the ground-truth
/// object geometry (for the router-dispatch validation of Fig. 6/9).
#[derive(Clone, Debug)]
pub struct Sample {
    pub pixels: Vec<f32>, // IMG*IMG*3
    pub label: usize,
    pub cx: i32,
    pub cy: i32,
    pub r: i32,
}

/// Generate the image for `seed` (deterministic; parity with data.gen_image).
pub fn gen_image(seed: u32) -> Sample {
    let mut rng = XorShift32::new(seed);
    let label = rng.randint(0, NUM_CLASSES as u32);
    let mut px = vec![0.0f32; IMG * IMG * 3];

    let base = 0.2 + 0.3 * rng.uniform();
    for y in 0..IMG {
        for x in 0..IMG {
            let checker = if ((x / 8) + (y / 8)) % 2 == 0 { 0.1 } else { 0.0 };
            let noise = 0.08 * rng.uniform();
            let v = base + checker + noise;
            for c in 0..3 {
                px[(y * IMG + x) * 3 + c] = v;
            }
        }
    }

    let r = rng.randint(5, 10) as i32;
    let cx = rng.randint((r + 1) as u32, (IMG as i32 - r - 1) as u32) as i32;
    let cy = rng.randint((r + 1) as u32, (IMG as i32 - r - 1) as u32) as i32;
    let col = [
        0.55 + 0.45 * rng.uniform(),
        0.15 * rng.uniform(),
        0.55 + 0.45 * rng.uniform(),
    ];
    for y in (cy - r)..=(cy + r) {
        for x in (cx - r)..=(cx + r) {
            if x >= 0
                && (x as usize) < IMG
                && y >= 0
                && (y as usize) < IMG
                && inside(label, x - cx, y - cy, r)
            {
                for c in 0..3 {
                    px[(y as usize * IMG + x as usize) * 3 + c] = col[c];
                }
            }
        }
    }
    Sample {
        pixels: px,
        label: label as usize,
        cx,
        cy,
        r,
    }
}

/// Generate a batch with seeds `seed0..seed0+n` as a flat (n, IMG, IMG, 3)
/// f32 buffer plus labels.
pub fn gen_batch(seed0: u32, n: usize) -> (Vec<f32>, Vec<usize>) {
    let mut xs = Vec::with_capacity(n * IMG * IMG * 3);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let s = gen_image(seed0 + i as u32);
        xs.extend_from_slice(&s.pixels);
        ys.push(s.label);
    }
    (xs, ys)
}

/// Token-level object mask at `patch` granularity (grid×grid bools) — the
/// ground truth against which router dispatch is scored.
pub fn object_mask(sample: &Sample, patch: usize) -> Vec<bool> {
    let grid = IMG / patch;
    let mut mask = vec![false; grid * grid];
    let (cx, cy, r) = (sample.cx, sample.cy, sample.r);
    for y in (cy - r)..=(cy + r) {
        for x in (cx - r)..=(cx + r) {
            if x >= 0
                && (x as usize) < IMG
                && y >= 0
                && (y as usize) < IMG
                && inside(sample.label as u32, x - cx, y - cy, r)
            {
                mask[(y as usize / patch) * grid + (x as usize / patch)] = true;
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = gen_image(42);
        let b = gen_image(42);
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn pixels_in_unit_range() {
        for seed in [1u32, 7, 1000] {
            let s = gen_image(seed);
            assert!(s.pixels.iter().all(|v| (0.0..=1.0).contains(v)));
        }
    }

    #[test]
    fn labels_cover_all_classes() {
        let mut seen = [false; NUM_CLASSES];
        for seed in 0..200u32 {
            seen[gen_image(seed).label] = true;
        }
        assert!(seen.iter().all(|s| *s), "{seen:?}");
    }

    #[test]
    fn object_mask_nonempty_and_not_full() {
        for seed in 0..20u32 {
            let s = gen_image(seed);
            let m = object_mask(&s, 4);
            let cnt = m.iter().filter(|b| **b).count();
            assert!(cnt > 0, "seed {seed} empty mask");
            assert!(cnt < m.len(), "seed {seed} full mask");
        }
    }

    #[test]
    fn batch_concatenates() {
        let (xs, ys) = gen_batch(5, 3);
        assert_eq!(xs.len(), 3 * IMG * IMG * 3);
        assert_eq!(ys.len(), 3);
        let one = gen_image(6);
        assert_eq!(&xs[IMG * IMG * 3..2 * IMG * IMG * 3], &one.pixels[..]);
    }
}
