//! Offline API stub for the `xla` crate (0.5.1 surface).
//!
//! The sandbox ships no XLA/PJRT native libraries, so this stub keeps the
//! runtime layer *compiling* while making the unavailability explicit at the
//! earliest possible moment: [`PjRtClient::cpu`] returns an error, which
//! `runtime::engine::Engine::new` surfaces with context. Everything
//! artifact-dependent (integration tests, table benches, examples) already
//! gates on `Manifest::available()` / engine construction and degrades to a
//! SKIP notice. Swapping in the real crate is a Cargo.toml change only —
//! no call-site edits.
//!
//! [`Literal`] is implemented for real (host buffers + reshape), since the
//! conversion helpers are cheap and keep the stub honest to the API.

#![allow(dead_code)]

use std::fmt;

/// Stub error: always a plain message.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what} unavailable: this build uses the vendored `xla` API stub \
             (rust/vendor/xla); install the real xla crate to enable PJRT execution"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element types the engine layer discriminates on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F32,
    F64,
}

/// Host-side literal payload.
#[derive(Clone, Debug)]
enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host literal: dims + data, enough to satisfy the conversion helpers.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

/// Numeric types a [`Literal`] can be built from / extracted to.
pub trait NativeType: Copy {
    fn literal_from(data: &[Self]) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
    fn element_type() -> ElementType;
}

impl NativeType for f32 {
    fn literal_from(data: &[Self]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: LiteralData::F32(data.to_vec()),
        }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            LiteralData::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }

    fn element_type() -> ElementType {
        ElementType::F32
    }
}

impl NativeType for i32 {
    fn literal_from(data: &[Self]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: LiteralData::I32(data.to_vec()),
        }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            LiteralData::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }

    fn element_type() -> ElementType {
        ElementType::S32
    }
}

/// Shape of an array literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::literal_from(data)
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        let have = match &self.data {
            LiteralData::F32(v) => v.len() as i64,
            LiteralData::I32(v) => v.len() as i64,
            LiteralData::Tuple(_) => return Err(Error("cannot reshape a tuple literal".into())),
        };
        if count != have {
            return Err(Error(format!("reshape {dims:?} mismatches {have} elements")));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Split a tuple literal into its parts.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.data {
            LiteralData::Tuple(parts) => Ok(std::mem::take(parts)),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
            LiteralData::Tuple(_) => return Err(Error("tuple literal has no array shape".into())),
        };
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty,
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HLO text parsing"))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device buffer handle returned by execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PJRT buffer readback"))
    }
}

/// Compiled executable (stub: never constructed in practice).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PJRT execution"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the stub's fail-fast point.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("XLA compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.array_shape().unwrap().ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }
}
