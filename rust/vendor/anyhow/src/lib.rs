//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The sandbox has no crates.io access (DESIGN.md §6), so this vendored shim
//! provides the subset of the real crate's API that the workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`. Error chains are
//! flattened into a single message string at conversion time — good enough
//! for CLI diagnostics, not a general-purpose replacement.

use std::fmt;

/// A flattened error: message plus any source-chain text, pre-joined.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring the real crate's trait.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn from_std_error_and_context() {
        let e = io_fail().context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
    }

    #[test]
    fn macros_format() {
        let name = "x";
        let e = anyhow!("missing key '{name}'");
        assert_eq!(e.to_string(), "missing key 'x'");
        let e2 = anyhow!("{} + {}", 1, 2);
        assert_eq!(e2.to_string(), "1 + 2");
        fn f() -> Result<()> {
            bail!("boom {}", 7)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
    }
}
