"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

This is the core correctness signal of the compile path — if these pass, the
HLO the Rust runtime executes computes the paper's primitives exactly.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import matadd, matshift, linattn, moe_mlp, ref


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------- matshift


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (32, 32, 32), (33, 47, 65), (1, 64, 128)])
def test_matshift_matches_ref(m, k, n):
    rng = np.random.default_rng(0)
    x = rand(rng, m, k)
    w = rand(rng, k, n)
    s, p = ref.pow2_quantize(jnp.asarray(w))
    got = matshift.matshift(jnp.asarray(x), s, p)
    want = ref.matshift_ref(jnp.asarray(x), s, p)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_pow2_quantize_roundtrip_within_octave():
    """Dequantized weight is within a factor of sqrt(2) of the original."""
    rng = np.random.default_rng(1)
    w = rand(rng, 32, 32) + 0.01
    s, p = ref.pow2_quantize(jnp.asarray(w))
    wq = np.asarray(ref.pow2_dequantize(s, p))
    mask = np.abs(w) > 2.0**-8
    ratio = np.abs(wq[mask]) / np.abs(w[mask])
    assert np.all(ratio > 0.70) and np.all(ratio < 1.42)
    assert np.all(np.sign(wq) == np.sign(np.where(w == 0, 1.0, w)))


def test_pow2_quantize_clips_exponent():
    w = jnp.asarray([[1e9, -1e-9, 0.0, 1.0]])
    s, p = ref.pow2_quantize(w)
    assert int(p.max()) <= 7 and int(p.min()) >= -8
    assert int(s[0, 1]) == -1 and int(s[0, 2]) == 1


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
    bm=st.sampled_from([8, 16, 32]),
)
def test_matshift_property(m, k, n, seed, bm):
    """Hypothesis sweep: arbitrary shapes and block sizes."""
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    w = rand(rng, k, n)
    s, p = ref.pow2_quantize(jnp.asarray(w))
    got = matshift.matshift(jnp.asarray(x), s, p, bm=bm, bn=16, bk=16)
    want = ref.matshift_ref(jnp.asarray(x), s, p)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ matadd


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (32, 32, 32), (17, 33, 9)])
def test_matadd_matches_ref(m, k, n):
    rng = np.random.default_rng(2)
    x = rand(rng, m, k)
    b = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    got = matadd.matadd(jnp.asarray(x), jnp.asarray(b))
    want = ref.matadd_ref(jnp.asarray(x), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matadd_binary_pm1_only():
    """±1 operand (no zeros) — the linear-attention case."""
    rng = np.random.default_rng(3)
    x = rand(rng, 16, 24)
    b = (rng.integers(0, 2, size=(24, 16)) * 2 - 1).astype(np.int8)
    got = matadd.matadd(jnp.asarray(x), jnp.asarray(b))
    want = ref.matadd_ref(jnp.asarray(x), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matadd_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    b = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    got = matadd.matadd(jnp.asarray(x), jnp.asarray(b), bm=16, bn=16, bk=16)
    want = ref.matadd_ref(jnp.asarray(x), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matadd_is_exact_for_integer_inputs():
    """Accumulation of integers is exact in f32 (no rounding surprises)."""
    rng = np.random.default_rng(4)
    x = rng.integers(-8, 9, size=(16, 32)).astype(np.float32)
    b = rng.integers(-1, 2, size=(32, 8)).astype(np.int8)
    got = np.asarray(matadd.matadd(jnp.asarray(x), jnp.asarray(b)))
    want = x @ b.astype(np.float32)
    assert np.array_equal(got, want)


# ----------------------------------------------------------------- linattn


@pytest.mark.parametrize("n,d", [(64, 16), (128, 32), (100, 16), (1, 8)])
def test_linattn_matches_ref(n, d):
    rng = np.random.default_rng(5)
    q = rand(rng, n, d)
    k = rand(rng, n, d)
    v = rand(rng, n, d)
    qb = np.asarray(ref.binary_quantize(jnp.asarray(q)))
    kb = np.asarray(ref.binary_quantize(jnp.asarray(k)))
    got = linattn.linattn(jnp.asarray(qb), jnp.asarray(kb), jnp.asarray(v), bt=32)
    want = ref.linattn_ref(jnp.asarray(qb), jnp.asarray(kb), jnp.asarray(v))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_linattn_linear_in_tokens():
    """Doubling identical tokens leaves per-token output unchanged.

    KV and Z double but so does the N normalizer — the linear-attention
    average is invariant to duplicating the token set.
    """
    rng = np.random.default_rng(6)
    n, d = 32, 16
    q = np.asarray(ref.binary_quantize(jnp.asarray(rand(rng, n, d))))
    k = np.asarray(ref.binary_quantize(jnp.asarray(rand(rng, n, d))))
    v = rand(rng, n, d)
    o1 = np.asarray(ref.linattn_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)))
    q2, k2, v2 = (np.concatenate([a, a], 0) for a in (q, k, v))
    o2 = np.asarray(ref.linattn_ref(jnp.asarray(q2), jnp.asarray(k2), jnp.asarray(v2)))
    np.testing.assert_allclose(o1, o2[:n], rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 96), d=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1))
def test_linattn_property(n, d, seed):
    rng = np.random.default_rng(seed)
    qb = (rng.integers(0, 2, size=(n, d)) * 2 - 1).astype(np.float32)
    kb = (rng.integers(0, 2, size=(n, d)) * 2 - 1).astype(np.float32)
    v = rand(rng, n, d)
    got = linattn.linattn(jnp.asarray(qb), jnp.asarray(kb), jnp.asarray(v), bt=32)
    want = ref.linattn_ref(jnp.asarray(qb), jnp.asarray(kb), jnp.asarray(v))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------- moe_mlp


def _moe_params(rng, d, h):
    gate_w = rand(rng, d, 2)
    w1m, b1m = rand(rng, d, h), rand(rng, 1, h)
    w2m, b2m = rand(rng, h, d), rand(rng, 1, d)
    s1, p1 = ref.pow2_quantize(jnp.asarray(rand(rng, d, h)))
    s2, p2 = ref.pow2_quantize(jnp.asarray(rand(rng, h, d)))
    b1s, b2s = rand(rng, 1, h), rand(rng, 1, d)
    return (
        jnp.asarray(gate_w),
        jnp.asarray(w1m),
        jnp.asarray(b1m),
        jnp.asarray(w2m),
        jnp.asarray(b2m),
        s1,
        p1,
        jnp.asarray(b1s),
        s2,
        p2,
        jnp.asarray(b2s),
    )


@pytest.mark.parametrize("n,d,h", [(64, 16, 32), (100, 32, 64), (5, 8, 16)])
def test_moe_mlp_matches_ref(n, d, h):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rand(rng, n, d))
    params = _moe_params(rng, d, h)
    got = moe_mlp.moe_mlp(x, *params, bt=32)
    want = ref.moe_mlp_ref(x, *params)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_moe_mlp_routes_all_tokens_to_one_expert():
    """A gate that always prefers expert 0 must equal the pure Mult MLP."""
    rng = np.random.default_rng(8)
    n, d, h = 32, 16, 32
    x = jnp.asarray(np.abs(rand(rng, n, d)) + 0.1)
    params = list(_moe_params(rng, d, h))
    gate = np.zeros((d, 2), np.float32)
    gate[:, 0] = 10.0  # positive x ⇒ expert 0 dominates
    params[0] = jnp.asarray(gate)
    got = np.asarray(moe_mlp.moe_mlp(x, *params, bt=16))
    _, w1m, b1m, w2m, b2m = params[0], params[1], params[2], params[3], params[4]
    y_m = np.maximum(np.asarray(x) @ np.asarray(w1m) + np.asarray(b1m), 0) @ np.asarray(
        w2m
    ) + np.asarray(b2m)
    # Gate value saturates to ~1.0 for a 10x margin.
    np.testing.assert_allclose(got, y_m, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 70), seed=st.integers(0, 2**31 - 1))
def test_moe_mlp_property(n, seed):
    rng = np.random.default_rng(seed)
    d, h = 16, 32
    x = jnp.asarray(rand(rng, n, d))
    params = _moe_params(rng, d, h)
    got = moe_mlp.moe_mlp(x, *params, bt=32)
    want = ref.moe_mlp_ref(x, *params)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
