"""Training machinery tests (tiny step counts — smoke + invariants)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile import train as T


def test_adam_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = T.adam_init(params)
    loss = lambda p: (p["w"] ** 2).sum()
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = T.adam_update(params, g, opt, lr=0.1)
    assert float(loss(params)) < 0.1


def test_adam_clips_exploding_gradients():
    params = {"w": jnp.asarray([1.0])}
    opt = T.adam_init(params)
    huge = {"w": jnp.asarray([1e12])}
    new, _ = T.adam_update(params, huge, opt, lr=0.1, clip=1.0)
    # after clipping, |update| ≤ lr / (sqrt(v̂)+eps) ≈ lr · bounded
    assert abs(float(new["w"][0]) - 1.0) < 1.0


def test_adam_survives_nan_gradients():
    params = {"w": jnp.asarray([1.0, 2.0])}
    opt = T.adam_init(params)
    bad = {"w": jnp.asarray([jnp.nan, 1.0])}
    new, _ = T.adam_update(params, bad, opt, lr=0.1)
    assert bool(jnp.isfinite(new["w"]).all())


def test_eval_acc_on_fresh_params_near_chance():
    cfg = M.MODELS["pvtv2_b0"]
    params = M.init_params(jax.random.PRNGKey(9), cfg)
    acc = T.eval_acc(params, cfg, M.VARIANTS["msa"], n=64)
    assert 0.0 <= acc <= 0.45  # chance is 0.125


@pytest.mark.slow
def test_short_training_improves_loss(tmp_path, monkeypatch):
    """5 gradient steps reduce the loss on a fixed batch (full train loop)."""
    monkeypatch.setattr(T, "TRAINED_DIR", str(tmp_path))
    monkeypatch.setattr(T, "RESULTS", str(tmp_path / "results.json"))
    import compile.params_io as pio

    monkeypatch.setattr(pio, "TRAINED_DIR", str(tmp_path))
    acc = T.train_classifier("pvtv2_b0", "msa", 5, log_every=5, bs=8)
    assert 0.0 <= acc <= 1.0
    assert (tmp_path / "pvtv2_b0_msa.npz").exists()
    import json

    rec = json.load(open(tmp_path / "results.json"))
    lc = rec["pvtv2_b0_msa"]["loss_curve"]
    assert len(lc) >= 2 and all(np.isfinite(lc))
