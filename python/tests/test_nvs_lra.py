"""NVS (GNT-style) and LRA model tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model_lra as LRA
from compile import model_nvs as NVS


# ------------------------------------------------------------------- NVS


@pytest.fixture(scope="module")
def nvs_params():
    return NVS.init_nvs_params(jax.random.PRNGKey(1))


def test_ray_trace_deterministic_and_bounded():
    scene = NVS.SCENES["orchids"]
    o, d = NVS.camera_rays(8, 0.1)
    a = NVS.ray_trace(scene, o, d)
    b = NVS.ray_trace(scene, o, d)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0.0 and a.max() <= 1.2
    assert a.shape == (64, 3)


def test_scene_has_visible_spheres():
    """At least some center-ish rays hit a sphere (colorful pixels)."""
    scene = NVS.SCENES["flower"]
    o, d = NVS.camera_rays(32, 0.0)
    img = NVS.ray_trace(scene, o, d).reshape(32, 32, 3)
    sat = img.max(-1) - img.min(-1)  # saturation proxy
    assert (sat > 0.15).sum() > 10


@pytest.mark.parametrize("vname", sorted(NVS.NVS_VARIANTS))
def test_nvs_forward_all_variants(nvs_params, vname):
    o, d = NVS.camera_rays(4, 0.0)
    rgb = NVS.nvs_forward(
        nvs_params, jnp.asarray(o), jnp.asarray(d), NVS.NVS_VARIANTS[vname]
    )
    assert rgb.shape == (16, 3)
    assert bool(jnp.isfinite(rgb).all())
    assert float(rgb.min()) >= 0.0 and float(rgb.max()) <= 1.0  # sigmoid head


def test_nvs_gradient_flows(nvs_params):
    o, d = NVS.camera_rays(4, 0.0)
    target = jnp.zeros((16, 3))

    def loss(p):
        rgb = NVS.nvs_forward(p, jnp.asarray(o), jnp.asarray(d), NVS.NVS_VARIANTS["add"])
        return ((rgb - target) ** 2).mean()

    g = jax.grad(loss)(nvs_params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert gn > 0.0


# ------------------------------------------------------------------- LRA


@pytest.fixture(scope="module")
def lra_params():
    return LRA.init_lra_params(jax.random.PRNGKey(2))


@pytest.mark.parametrize("task", LRA.LRA_TASKS)
def test_lra_tasks_generate_valid_labels(task):
    xs, ys = LRA.gen_task(task, seed=3, n=16)
    assert xs.shape == (16, LRA.LRA_CFG.seq)
    assert xs.min() >= 0 and xs.max() < LRA.VOCAB
    assert ys.min() >= 0 and ys.max() < LRA.LRA_CFG.classes
    # labels are not constant (task is learnable)
    xs2, ys2 = LRA.gen_task(task, seed=4, n=64)
    assert len(set(ys2.tolist())) > 1


@pytest.mark.parametrize("attn", LRA.LRA_ATTNS)
def test_lra_forward_all_families(lra_params, attn):
    xs, _ = LRA.gen_task("text", seed=5, n=2)
    logits = LRA.lra_forward(lra_params, jnp.asarray(xs), attn)
    assert logits.shape == (2, LRA.LRA_CFG.classes)
    assert bool(jnp.isfinite(logits).all())


def test_lra_families_differ():
    """Different attention families produce different functions."""
    p = LRA.init_lra_params(jax.random.PRNGKey(3))
    xs, _ = LRA.gen_task("text", seed=6, n=1)
    outs = {
        attn: np.asarray(LRA.lra_forward(p, jnp.asarray(xs), attn))
        for attn in LRA.LRA_ATTNS
    }
    assert not np.allclose(outs["transformer"], outs["shiftadd"])
    assert not np.allclose(outs["transformer"], outs["linformer"])


def test_retrieval_task_balanced():
    _, ys = LRA.gen_task("retrieval", seed=8, n=128)
    frac = ys.mean()
    assert 0.25 < frac < 0.75
