"""Flat-params export tests: ``.sap`` byte layout + round-trip.

The ``.sap`` blob is the Python→Rust weight hand-off (``bundle::params``
in the Rust runtime); these tests pin the byte layout so both sides stay
in sync. The trained-checkpoint test skips cleanly when no ``.npz``
artifacts exist under ``python/trained/``.
"""

import glob
import os
import struct

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="params_io imports jax at module load")

from compile import params_io as P


def tree():
    return {
        "stem": {
            "w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.zeros(4, np.float32),
        },
        "blocks": [
            {"g": np.full(5, 2.5, np.float32)},
            {"g": np.linspace(-1, 1, 5).astype(np.float32)},
        ],
        "scale": np.float32(3.0),
    }


def test_export_flat_round_trips(tmp_path):
    path = str(tmp_path / "p.sap")
    P.export_flat(tree(), path)
    back = P.load_flat(path)
    flat = P.flatten(tree())
    assert sorted(back) == sorted(flat)
    for k, v in flat.items():
        want = np.asarray(v, dtype=np.float32)
        assert back[k].dtype == np.float32
        assert back[k].shape == want.shape
        np.testing.assert_array_equal(back[k], want)


def test_header_layout_matches_rust_reader(tmp_path):
    path = str(tmp_path / "h.sap")
    P.export_flat({"a": np.ones((2, 2), np.float32)}, path)
    with open(path, "rb") as f:
        blob = f.read()
    assert blob[:8] == b"SAPF0001"
    assert struct.unpack_from("<I", blob, 8) == (1,)
    # u16 keylen + key + u8 ndim + 2 u32 dims + 4 f32s — and nothing after.
    assert struct.unpack_from("<H", blob, 12) == (1,)
    assert blob[14:15] == b"a"
    assert blob[15] == 2
    assert struct.unpack_from("<II", blob, 16) == (2, 2)
    assert len(blob) == 24 + 16


def test_keys_are_sorted_on_disk(tmp_path):
    # The Rust reader rejects unsorted entries, so order is part of the
    # format: the first key on disk must be the lexicographically smallest.
    path = str(tmp_path / "s.sap")
    P.export_flat({"z": np.zeros(1, np.float32), "a": np.ones(1, np.float32)}, path)
    with open(path, "rb") as f:
        blob = f.read()
    (l0,) = struct.unpack_from("<H", blob, 12)
    assert blob[14 : 14 + l0].decode("utf-8") == "a"


def test_jax_arrays_export_too(tmp_path):
    import jax.numpy as jnp

    path = str(tmp_path / "j.sap")
    P.export_flat({"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}, path)
    back = P.load_flat(path)
    np.testing.assert_array_equal(
        back["w"], np.arange(6, dtype=np.float32).reshape(2, 3)
    )


def test_load_flat_rejects_bad_magic(tmp_path):
    path = str(tmp_path / "bad.sap")
    with open(path, "wb") as f:
        f.write(b"NOTSAPF0" + b"\x00" * 8)
    with pytest.raises(ValueError, match="bad magic"):
        P.load_flat(path)


def test_trained_checkpoint_exports_to_flat(tmp_path):
    """Trained ``.npz`` checkpoints (if any) export losslessly to ``.sap``."""
    npzs = sorted(glob.glob(os.path.join(P.TRAINED_DIR, "*.npz")))
    if not npzs:
        pytest.skip("no trained checkpoints under python/trained/")
    flat = {k: np.asarray(v, np.float32) for k, v in np.load(npzs[0]).items()}
    path = str(tmp_path / "trained.sap")
    P.export_flat(flat, path)
    back = P.load_flat(path)
    assert sorted(back) == sorted(flat)
    for k, v in flat.items():
        np.testing.assert_array_equal(back[k], v)
