"""Synthetic dataset tests + the Python↔Rust generator parity contract."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D


def test_deterministic():
    a, la = D.gen_image(7)
    b, lb = D.gen_image(7)
    np.testing.assert_array_equal(a, b)
    assert la == lb


def test_pixel_range_and_shape():
    img, label = D.gen_image(3)
    assert img.shape == (32, 32, 3)
    assert 0 <= label < D.NUM_CLASSES
    assert img.min() >= 0.0 and img.max() <= 1.0


def test_all_classes_reachable():
    labels = {D.gen_image(s)[1] for s in range(200)}
    assert labels == set(range(D.NUM_CLASSES))


def test_object_mask_overlaps_object_pixels():
    for seed in range(10):
        img, label = D.gen_image(seed)
        mask = D.object_mask(seed, patch=4)
        assert mask.any() and not mask.all()
        # masked patches contain the (magenta-ish) object color: red/blue
        # channels high, green low somewhere inside
        ys, xs = np.where(mask)
        found = False
        for y, x in zip(ys, xs):
            patch = img[y * 4 : (y + 1) * 4, x * 4 : (x + 1) * 4]
            if (patch[..., 0] > 0.5).any() and (patch[..., 1] < 0.2).any():
                found = True
                break
        assert found, f"seed {seed}: no object pixels under mask"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_xorshift_period_and_range(seed):
    rng = D.Rng(seed)
    vals = [rng.uniform() for _ in range(100)]
    assert all(0.0 <= v < 1.0 for v in vals)
    # not constant
    assert len({round(v, 6) for v in vals}) > 50


def test_xorshift_known_vector():
    """Pinned first draws for seed 1 — the Rust mirror asserts the same
    stream (rust/src/util/rng.rs). If this changes, both sides break."""
    rng = D.Rng(1)
    a = rng.next_u32()
    s = 1
    s ^= (s << 13) & 0xFFFFFFFF
    s ^= s >> 17
    s ^= (s << 5) & 0xFFFFFFFF
    assert a == s


def test_batch_seeding_matches_single():
    xs, ys = D.gen_batch(50, 3)
    img, label = D.gen_image(51)
    np.testing.assert_array_equal(xs[1], img)
    assert ys[1] == label


@pytest.mark.parametrize("shape_id", range(8))
def test_every_shape_rasterizes_nonempty(shape_id):
    # Window strictly larger than the radius: a square of r=8 fills ±8 but
    # must not fill ±10.
    pts = [
        (dx, dy)
        for dx in range(-10, 11)
        for dy in range(-10, 11)
        if D._inside(shape_id, dx, dy, 8)
    ]
    assert len(pts) > 4
    assert len(pts) < 21 * 21  # not everything
