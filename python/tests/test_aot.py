"""AOT path tests: HLO-text lowering contract + manifest integrity.

These run the actual lowering machinery on one tiny function (fast) and, if
`artifacts/manifest.json` exists, validate the full manifest against disk.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_fn_produces_hlo_text():
    text = aot.lower_fn(
        lambda x: (x @ x + 1.0,), (jax.ShapeDtypeStruct((4, 4), jnp.float32),)
    )
    assert "HloModule" in text
    assert "ROOT" in text
    # return_tuple contract: root is a tuple
    assert "tuple(" in text or "tuple " in text


def test_lower_pallas_kernel_to_hlo():
    """Pallas (interpret) lowers into plain HLO — the L1→HLO contract."""
    from compile.kernels import matadd
    import numpy as np

    b = jnp.asarray(np.ones((8, 8), np.int8))

    def fn(x):
        return (matadd.matadd(x, b, bm=8, bn=8, bk=8),)

    text = aot.lower_fn(fn, (jax.ShapeDtypeStruct((8, 8), jnp.float32),))
    assert "HloModule" in text
    # no TPU custom-calls — must be executable on the CPU PJRT plugin
    assert "mosaic" not in text.lower()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_entries_exist_on_disk():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["models"], "empty manifest"
    for name, entry in manifest["models"].items():
        path = os.path.join(ART, entry["path"])
        assert os.path.exists(path), f"{name} missing {path}"
        assert entry["inputs"], f"{name} has no inputs"
        for spec in entry["inputs"]:
            assert all(d > 0 for d in spec["shape"])


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_serve_topology_complete():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    serve = manifest.get("serve", {})
    if not serve:
        pytest.skip("no serving topology")
    models = manifest["models"]
    for b in serve["batch_buckets"]:
        assert f"serve_stem_bs{b}" in models
        assert f"serve_head_bs{b}" in models
        for i in range(serve["depth"]):
            assert f"serve_blk{i}_attn_bs{b}" in models
            assert f"serve_blk{i}_premlp_bs{b}" in models
    for i in range(serve["depth"]):
        for nb in serve["token_buckets"]:
            assert f"serve_expert_mult_blk{i}_n{nb}" in models
            assert f"serve_expert_shift_blk{i}_n{nb}" in models
