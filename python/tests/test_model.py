"""L2 model tests: variant forwards, pallas/dense path parity, STE
quantizers, LL-loss behavior, and parameter I/O."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import data as D
from compile import model as M
from compile import params_io


CFG = M.MODELS["pvtv2_b0"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def batch():
    return D.gen_batch(0, 2)


@pytest.mark.parametrize("vname", sorted(M.VARIANTS))
def test_forward_shapes_all_variants(params, batch, vname):
    xs, _ = batch
    logits, aux = M.forward(params, jnp.asarray(xs), CFG, M.VARIANTS[vname])
    assert logits.shape == (2, CFG.num_classes)
    assert bool(jnp.isfinite(logits).all())
    if M.VARIANTS[vname].mlp == "moe":
        assert len(aux["gates"]) == CFG.depth
        g = aux["gates"][0]
        assert g.shape == (2, CFG.tokens, 2)
        np.testing.assert_allclose(np.asarray(g.sum(-1)), 1.0, rtol=1e-5)


@pytest.mark.parametrize(
    "vname", ["msa", "linear", "add_quant", "add_ksh_moe_both", "add_quant_shift_both"]
)
def test_pallas_path_matches_dense(params, batch, vname):
    """The L1-kernel path and the jnp path must agree — this is what makes
    the AOT'd pallas HLO interchangeable with the dense HLO."""
    xs, _ = batch
    var = M.VARIANTS[vname]
    a, _ = M.forward(params, jnp.asarray(xs), CFG, var, use_pallas=False)
    b, _ = M.forward(params, jnp.asarray(xs), CFG, var, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_ste_pow2_values_are_powers_of_two(params):
    w = params["blocks"][0]["w1"]
    wq = np.asarray(M.ste_pow2(w))
    logs = np.log2(np.abs(wq[wq != 0]))
    np.testing.assert_allclose(logs, np.round(logs), atol=1e-6)


def test_ste_gradients_flow_through_quantizers():
    w = jnp.asarray([[0.3, -0.7], [1.2, -0.1]])
    g = jax.grad(lambda w_: (M.ste_pow2(w_) ** 2).sum())(w)
    assert bool(jnp.all(jnp.abs(g) > 0))
    x = jnp.asarray([0.5, -0.5])
    gs = jax.grad(lambda x_: M.ste_sign(x_).sum())(x)
    np.testing.assert_allclose(np.asarray(gs), 1.0)


def test_ll_loss_zero_when_balanced_and_positive_when_skewed():
    alphas = jnp.asarray([0.5, 0.5])
    balanced = jnp.full((1, 64, 2), 0.5)
    assert float(M.ll_loss(balanced, alphas)) < 1e-6
    skewed = jnp.concatenate(
        [jnp.full((1, 64, 1), 0.95), jnp.full((1, 64, 1), 0.05)], axis=-1
    )
    assert float(M.ll_loss(skewed, alphas)) > 0.1


def test_ll_loss_prefers_latency_proportional_split():
    """With a 4:1 latency ratio, a router that sends ~20% of tokens (hard
    top-1) to the slow Mult expert scores lower than a 50/50 router — the
    mechanism behind Table 7."""
    alphas = jnp.asarray([0.8, 0.2])  # Mult 4x slower

    def population(frac_mult, n=1000):
        n_m = int(n * frac_mult)
        mult = jnp.tile(jnp.asarray([[0.9, 0.1]]), (n_m, 1))
        shift = jnp.tile(jnp.asarray([[0.1, 0.9]]), (n - n_m, 1))
        return jnp.concatenate([mult, shift], 0)[None]

    balanced = float(M.ll_loss(population(0.2), alphas))
    even = float(M.ll_loss(population(0.5), alphas))
    assert balanced < even, (balanced, even)


def test_classification_loss_decreases_on_easy_overfit(params):
    xs, ys = D.gen_batch(100, 8)
    var = M.VARIANTS["msa"]
    alphas = jnp.asarray([0.5, 0.5])
    loss_fn = lambda p: M.classification_loss(
        p, jnp.asarray(xs), jnp.asarray(ys), CFG, var, alphas
    )[0]
    l0, g = jax.value_and_grad(loss_fn)(params)
    p1 = jax.tree.map(lambda p_, g_: p_ - 0.01 * g_, params, g)
    l1 = loss_fn(p1)
    assert float(l1) < float(l0)


def test_params_io_roundtrip(params, tmp_path):
    path = str(tmp_path / "p.npz")
    params_io.save_params(params, path)
    flat = dict(np.load(path))
    restored = params_io.unflatten_like(params, flat)
    np.testing.assert_array_equal(
        np.asarray(params["blocks"][1]["wq"]), np.asarray(restored["blocks"][1]["wq"])
    )
    assert len(restored["blocks"]) == CFG.depth


def test_variant_tags_unique():
    tags = [v.tag() for v in M.VARIANTS.values()]
    assert len(tags) == len(set(tags))


def test_model_zoo_scaling():
    """Config family preserves the paper's size ordering."""
    p0 = M.MODELS["pvtv2_b0"]
    p1 = M.MODELS["pvtv2_b1"]
    p2 = M.MODELS["pvtv2_b2"]
    assert p0.dim < p1.dim <= p2.dim
    assert p2.depth > p0.depth
