"""Save/load parameter pytrees as .npz (flattened dotted keys).

Trained weights live in ``python/trained/<model>_<variant>.npz``; if absent,
:func:`load_params` falls back to a *seeded* random init so `make artifacts`
is reproducible with or without the training step (latency benches do not
need trained weights; accuracy tables do — EXPERIMENTS.md records which runs
used trained checkpoints).
"""

from __future__ import annotations

import os
import struct
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

TRAINED_DIR = os.path.join(os.path.dirname(__file__), "..", "trained")


def flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def unflatten_like(template: Any, flat: Dict[str, np.ndarray], prefix: str = ""):
    if isinstance(template, dict):
        return {
            k: unflatten_like(v, flat, f"{prefix}{k}.") for k, v in template.items()
        }
    if isinstance(template, (list, tuple)):
        return [
            unflatten_like(v, flat, f"{prefix}{i}.") for i, v in enumerate(template)
        ]
    return jnp.asarray(flat[prefix[:-1]])


def save_params(params: Any, path: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez(path, **flatten(params))


# --- flat binary export (.sap) ---------------------------------------------
#
# The byte format of the Rust runtime's ``bundle::params::FlatParams`` (see
# rust/src/bundle/params.rs): magic ``SAPF0001``, u32 LE entry count, then per
# dotted key in strictly ascending order: u16 LE key length + utf-8 key,
# u8 ndim, ndim x u32 LE dims, row-major f32 LE data. ``shiftaddvit bundle
# pack --params out.sap`` wraps the result in a signed .sabundle.

FLAT_MAGIC = b"SAPF0001"


def export_flat(params: Any, path: str) -> None:
    """Write a parameter pytree as a Rust-loadable ``.sap`` flat blob."""
    flat = flatten(params)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "wb") as f:
        f.write(FLAT_MAGIC)
        f.write(struct.pack("<I", len(flat)))
        for key in sorted(flat):
            # asarray, not ascontiguousarray: the latter promotes 0-d
            # scalars to shape (1,); tobytes() emits C order regardless.
            arr = np.asarray(flat[key], dtype="<f4")
            name = key.encode("utf-8")
            f.write(struct.pack("<H", len(name)))
            f.write(name)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load_flat(path: str) -> Dict[str, np.ndarray]:
    """Read a ``.sap`` flat blob back into ``{dotted key: float32 array}``."""
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:8] != FLAT_MAGIC:
        raise ValueError(f"{path}: bad magic (not a SAPF0001 flat params blob)")
    (count,) = struct.unpack_from("<I", blob, 8)
    pos = 12
    out: Dict[str, np.ndarray] = {}
    for _ in range(count):
        (name_len,) = struct.unpack_from("<H", blob, pos)
        pos += 2
        name = blob[pos : pos + name_len].decode("utf-8")
        pos += name_len
        ndim = blob[pos]
        pos += 1
        dims = struct.unpack_from(f"<{ndim}I", blob, pos)
        pos += 4 * ndim
        numel = int(np.prod(dims, dtype=np.int64))
        arr = np.frombuffer(blob, dtype="<f4", count=numel, offset=pos)
        pos += 4 * numel
        out[name] = arr.reshape(dims).copy()
    if pos != len(blob):
        raise ValueError(f"{path}: {len(blob) - pos} trailing bytes")
    return out


def trained_path(model: str, variant: str) -> str:
    return os.path.join(TRAINED_DIR, f"{model}_{variant}.npz")


def load_params(model: str, variant: str, cfg) -> Any:
    """Trained checkpoint if present, else deterministic random init."""
    from . import model as M

    template = M.init_params(jax.random.PRNGKey(hash(model) % (2**31)), cfg)
    path = trained_path(model, variant)
    if os.path.exists(path):
        flat = dict(np.load(path))
        return unflatten_like(template, flat)
    # Fall back to the *base* checkpoint of this model if one exists (e.g.
    # variant-specific finetune missing but stage-0 MSA weights present).
    base = trained_path(model, "msa")
    if os.path.exists(base):
        flat = dict(np.load(base))
        return unflatten_like(template, flat)
    return template


def load_params_nvs(scene: str, variant: str):
    """NVS checkpoint for (scene, variant), falling back like load_params."""
    from . import model_nvs as NVS

    template = NVS.init_nvs_params(jax.random.PRNGKey(7))
    for name in (f"nvs_{scene}_{variant}", f"nvs_{scene}_gnt"):
        path = os.path.join(TRAINED_DIR, f"{name}.npz")
        if os.path.exists(path):
            return unflatten_like(template, dict(np.load(path)))
    return template


def load_params_lra(task: str, variant: str):
    """LRA checkpoint for (task, variant), falling back to random init."""
    from . import model_lra as LRA

    template = LRA.init_lra_params(jax.random.PRNGKey(11))
    path = os.path.join(TRAINED_DIR, f"lra_{task}_{variant}.npz")
    if os.path.exists(path):
        return unflatten_like(template, dict(np.load(path)))
    return template
