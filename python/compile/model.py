"""L2 — the ShiftAddViT model family in JAX.

Implements the paper's reparameterization ladder as *variants* of one
transformer backbone (Fig. 1):

- attention: ``msa`` → ``linear`` (Q(KV) order) → ``linear_add`` (binarized
  Q/K via vanilla ``quant`` [27] or ``ksh`` [34] → MatAdd accumulations),
- the four attention Linears: ``mult`` or ``shift`` (s·2^P weights),
- MLPs: ``mult``, ``shift``, or ``moe`` (Mult + Shift experts, top-1 router),
- a parallel DWConv on the V branch for linear variants (<1% MACs).

Two numerically-identical execution paths:

- ``use_pallas=False`` — pure jnp (fast for training / quick eval),
- ``use_pallas=True``  — routes the shift/add/linattn/moe ops through the L1
  Pallas kernels so the AOT-lowered HLO contains the paper's primitives.

Params are plain nested dicts of jnp arrays; model configs are tiny
(CPU-trainable) analogues of PVTv2-B0/B1/B2, PVTv1-T and DeiT-T, with the
scaling ratios between them preserved.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .kernels import linattn as linattn_k
from .kernels import matadd as matadd_k
from .kernels import matshift as matshift_k
from .kernels import moe_mlp as moe_k
from .kernels import ref

# --------------------------------------------------------------------------
# Configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Backbone hyperparameters (a tiny, CPU-trainable ViT)."""

    name: str
    img: int = 32
    patch: int = 4
    dim: int = 32
    depth: int = 2
    heads: int = 2
    mlp_ratio: int = 4
    num_classes: int = 8
    hash_bits: int = 0  # KSH projection width; 0 ⇒ use head_dim

    @property
    def tokens(self) -> int:
        return (self.img // self.patch) ** 2

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads


# Tiny analogues. Width/depth ratios follow the real families
# (B0 < B1 < B2; PVTv1-T between B0 and B1; DeiT-T isotropic).
MODELS: Dict[str, ModelConfig] = {
    "pvtv2_b0": ModelConfig(name="pvtv2_b0", dim=32, depth=2, heads=2),
    "pvtv2_b1": ModelConfig(name="pvtv2_b1", dim=48, depth=2, heads=2),
    "pvtv2_b2": ModelConfig(name="pvtv2_b2", dim=64, depth=4, heads=4),
    "pvtv1_t": ModelConfig(name="pvtv1_t", dim=40, depth=3, heads=2),
    "deit_t": ModelConfig(name="deit_t", dim=64, depth=3, heads=4),
}


@dataclasses.dataclass(frozen=True)
class Variant:
    """One row of Table 4/6 — which primitives replace which multiplications.

    attn:        'msa' | 'linear' | 'linear_add'
    qk_bin:      'none' | 'quant' | 'ksh'       (only for linear_add)
    attn_linear: 'mult' | 'shift'               (the 4 attention Linears)
    mlp:         'mult' | 'shift' | 'moe'
    """

    attn: str = "msa"
    qk_bin: str = "none"
    attn_linear: str = "mult"
    mlp: str = "mult"

    def tag(self) -> str:
        parts = [self.attn]
        if self.attn == "linear_add":
            parts.append(self.qk_bin)
        if self.attn_linear == "shift":
            parts.append("shiftattn")
        parts.append(self.mlp)
        return "_".join(parts)


# The paper's main rows (Tables 2/4/6).
VARIANTS: Dict[str, Variant] = {
    "msa": Variant(),
    "linear": Variant(attn="linear"),
    "add_ksh": Variant(attn="linear_add", qk_bin="ksh"),
    "add_quant": Variant(attn="linear_add", qk_bin="quant"),
    "add_ksh_shiftattn": Variant(attn="linear_add", qk_bin="ksh", attn_linear="shift"),
    "add_quant_shift_both": Variant(
        attn="linear_add", qk_bin="quant", attn_linear="shift", mlp="shift"
    ),
    "add_ksh_shiftattn_moe": Variant(
        attn="linear_add", qk_bin="ksh", attn_linear="shift", mlp="moe"
    ),
    "add_ksh_moe_both": Variant(attn="linear_add", qk_bin="ksh", mlp="moe"),
    "add_quant_moe_both": Variant(attn="linear_add", qk_bin="quant", mlp="moe"),
    "shift_mlp": Variant(attn="linear", mlp="shift"),
    "moe_mlp": Variant(attn="linear", mlp="moe"),
}


# --------------------------------------------------------------------------
# Initialization
# --------------------------------------------------------------------------


def _dense_init(key, fan_in, fan_out):
    scale = (2.0 / (fan_in + fan_out)) ** 0.5
    return scale * jax.random.normal(key, (fan_in, fan_out), jnp.float32)


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    """Initialize the full parameter pytree for any variant.

    All variants share the same pytree so reparameterization = finetuning the
    same weights under a different forward interpretation (the paper starts
    from pre-trained ViTs; we start each stage from the previous stage).
    """
    keys = iter(jax.random.split(key, 16 + 32 * cfg.depth))
    patch_dim = cfg.patch * cfg.patch * 3
    p: Dict[str, Any] = {
        "embed_w": _dense_init(next(keys), patch_dim, cfg.dim),
        "embed_b": jnp.zeros((cfg.dim,)),
        "pos": 0.02 * jax.random.normal(next(keys), (cfg.tokens, cfg.dim)),
        "ksh_proj": jax.random.normal(
            next(keys), (cfg.head_dim, cfg.hash_bits or cfg.head_dim)
        )
        / (cfg.head_dim**0.5),
        "head_w": _dense_init(next(keys), cfg.dim, cfg.num_classes),
        "head_b": jnp.zeros((cfg.num_classes,)),
        "norm_g": jnp.ones((cfg.dim,)),
        "norm_b": jnp.zeros((cfg.dim,)),
        "blocks": [],
    }
    h = cfg.dim * cfg.mlp_ratio
    for _ in range(cfg.depth):
        blk = {
            "ln1_g": jnp.ones((cfg.dim,)),
            "ln1_b": jnp.zeros((cfg.dim,)),
            "ln2_g": jnp.ones((cfg.dim,)),
            "ln2_b": jnp.zeros((cfg.dim,)),
            "wq": _dense_init(next(keys), cfg.dim, cfg.dim),
            "wk": _dense_init(next(keys), cfg.dim, cfg.dim),
            "wv": _dense_init(next(keys), cfg.dim, cfg.dim),
            "wo": _dense_init(next(keys), cfg.dim, cfg.dim),
            "bq": jnp.zeros((cfg.dim,)),
            "bk": jnp.zeros((cfg.dim,)),
            "bv": jnp.zeros((cfg.dim,)),
            "bo": jnp.zeros((cfg.dim,)),
            # DWConv 3x3 on the V branch (linear variants only).
            "dw": 0.1 * jax.random.normal(next(keys), (3, 3, cfg.dim)),
            # MLP (mult expert / dense path).
            "w1": _dense_init(next(keys), cfg.dim, h),
            "b1": jnp.zeros((h,)),
            "w2": _dense_init(next(keys), h, cfg.dim),
            "b2": jnp.zeros((cfg.dim,)),
            # Shift expert (separate weights — the MoE's second expert; for
            # the pure-shift MLP variant, these mirror w1/w2 after stage-2
            # conversion, see train.py::convert_mlp_to_shift).
            "w1s": _dense_init(next(keys), cfg.dim, h),
            "b1s": jnp.zeros((h,)),
            "w2s": _dense_init(next(keys), h, cfg.dim),
            "b2s": jnp.zeros((cfg.dim,)),
            # MoE router.
            "gate_w": 0.02 * jax.random.normal(next(keys), (cfg.dim, 2)),
        }
        p["blocks"].append(blk)
    return p


# --------------------------------------------------------------------------
# Quantization with straight-through estimators (training path)
# --------------------------------------------------------------------------


def ste_pow2(w):
    """Power-of-two reparameterization with a straight-through gradient."""
    s, p = ref.pow2_quantize(w)
    wq = ref.pow2_dequantize(s, p)
    return w + jax.lax.stop_gradient(wq - w)


def ste_sign(x):
    """Binarize to {-1,+1} with a straight-through gradient (clipped)."""
    b = ref.binary_quantize(x)
    return x + jax.lax.stop_gradient(b - x)


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def linear(x, w, b, kind: str, use_pallas: bool):
    """A (possibly shift-reparameterized) linear layer on (..., K) inputs."""
    if kind == "mult":
        return x @ w + b
    if kind == "shift":
        if use_pallas:
            s, p = ref.pow2_quantize(w)
            flat = x.reshape(-1, x.shape[-1])
            y = matshift_k.matshift(flat, s, p)
            return y.reshape(*x.shape[:-1], w.shape[1]) + b
        return x @ ste_pow2(w) + b
    raise ValueError(kind)


def dwconv_tokens(x, dw, grid: int):
    """Depthwise 3×3 conv over the token grid; x: (B, N, d), N = grid²."""
    b, n, d = x.shape
    img = x.reshape(b, grid, grid, d)
    out = jax.lax.conv_general_dilated(
        img,
        dw[:, :, None, :],  # (3, 3, 1, d) — HWIO with 1 input feature/group
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=d,
    )
    return out.reshape(b, n, d)


def attention(params, x, cfg: ModelConfig, var: Variant, use_pallas: bool, grid: int):
    """One attention module on (B, N, d) tokens."""
    b, n, d = x.shape
    hd = cfg.head_dim
    lk = var.attn_linear
    q = linear(x, params["wq"], params["bq"], lk, use_pallas)
    k = linear(x, params["wk"], params["bk"], lk, use_pallas)
    v = linear(x, params["wv"], params["bv"], lk, use_pallas)

    def split(t):  # (B, N, d) -> (B, H, N, hd)
        return t.reshape(b, n, cfg.heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)

    if var.attn == "msa":
        oh = jax.vmap(jax.vmap(ref.softmax_attn_ref))(qh, kh, vh)
    elif var.attn == "linear":
        # Non-binarized linear attention: ReLU features, Q(KV) order.
        fq, fk = jax.nn.relu(qh) + 1e-3, jax.nn.relu(kh) + 1e-3
        kv = jnp.einsum("bhnd,bhne->bhde", fk, vh)
        z = fk.sum(axis=2)  # (B, H, hd)
        num = jnp.einsum("bhnd,bhde->bhne", fq, kv)
        den = jnp.einsum("bhnd,bhd->bhn", fq, z)[..., None]
        oh = num / (den + 1e-6)
    elif var.attn == "linear_add":
        if var.qk_bin == "ksh":
            proj = params_global["ksh_proj"]
            qc = ste_sign(jnp.einsum("bhnd,de->bhne", qh, proj))
            kc = ste_sign(jnp.einsum("bhnd,de->bhne", kh, proj))
        elif var.qk_bin == "quant":
            qc, kc = ste_sign(qh), ste_sign(kh)
        else:
            raise ValueError(var.qk_bin)
        if use_pallas:
            fn = lambda qq, kk, vv: linattn_k.linattn(qq, kk, vv, bt=min(64, n))
            oh = jax.vmap(jax.vmap(fn))(qc, kc, vh)
        else:
            oh = jax.vmap(jax.vmap(ref.linattn_ref))(qc, kc, vh)
    else:
        raise ValueError(var.attn)

    out = oh.transpose(0, 2, 1, 3).reshape(b, n, d)
    if var.attn != "msa":
        # Parallel DWConv on the V branch (local features; <1% of MACs).
        out = out + dwconv_tokens(v, params["dw"], grid)
    return linear(out, params["wo"], params["bo"], lk, use_pallas)


def mlp(params, x, var: Variant, use_pallas: bool):
    """One MLP module on (B, N, d) tokens. Returns (y, gates-or-None)."""
    b, n, d = x.shape
    if var.mlp == "mult":
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"], None
    if var.mlp == "shift":
        h = jax.nn.relu(
            linear(x, params["w1s"], params["b1s"], "shift", use_pallas)
        )
        return linear(h, params["w2s"], params["b2s"], "shift", use_pallas), None
    if var.mlp == "moe":
        flat = x.reshape(b * n, d)
        logits = flat @ params["gate_w"]
        pgate = jax.nn.softmax(logits, axis=-1)
        if use_pallas:
            s1, p1 = ref.pow2_quantize(params["w1s"])
            s2, p2 = ref.pow2_quantize(params["w2s"])
            y = moe_k.moe_mlp(
                flat,
                params["gate_w"],
                params["w1"],
                params["b1"][None, :],
                params["w2"],
                params["b2"][None, :],
                s1,
                p1,
                params["b1s"][None, :],
                s2,
                p2,
                params["b2s"][None, :],
                bt=min(64, b * n),
            )
        else:
            # Dense-masked top-1 routing, differentiable through the gate
            # value (the paper's G(x) = p_i · 1{p_i ≥ p_j}).
            mult_wins = (pgate[:, 0:1] >= pgate[:, 1:2]).astype(flat.dtype)
            gval = jnp.where(mult_wins > 0, pgate[:, 0:1], pgate[:, 1:2])
            h_m = jax.nn.relu(flat @ params["w1"] + params["b1"])
            y_m = h_m @ params["w2"] + params["b2"]
            w1q, w2q = ste_pow2(params["w1s"]), ste_pow2(params["w2s"])
            h_s = jax.nn.relu(flat @ w1q + params["b1s"])
            y_s = h_s @ w2q + params["b2s"]
            y = gval * (mult_wins * y_m + (1.0 - mult_wins) * y_s)
        return y.reshape(b, n, d), pgate.reshape(b, n, 2)
    raise ValueError(var.mlp)


# ``attention`` needs the global ksh projection; passed via this module-level
# slot set by ``forward`` (kept out of the block params so all blocks share
# one hash family, as in Ecoformer).
params_global: Dict[str, Any] = {}


def forward(params, x, cfg: ModelConfig, var: Variant, use_pallas: bool = False):
    """Classification forward.

    x: (B, img, img, 3) float32 → logits (B, num_classes).
    Returns ``(logits, aux)`` where aux["gates"] is a list of per-MoE-layer
    gate tensors (B, N, 2) for the LL-loss and the dispatch visualisation.
    """
    global params_global
    params_global = params
    b = x.shape[0]
    grid = cfg.img // cfg.patch

    # Patch embedding: (B, H, W, 3) -> (B, N, patch²·3) -> (B, N, d).
    ph = x.reshape(b, grid, cfg.patch, grid, cfg.patch, 3)
    ph = ph.transpose(0, 1, 3, 2, 4, 5).reshape(b, grid * grid, -1)
    t = ph @ params["embed_w"] + params["embed_b"] + params["pos"]

    gates = []
    for blk in params["blocks"]:
        a = attention(blk, layer_norm(t, blk["ln1_g"], blk["ln1_b"]), cfg, var, use_pallas, grid)
        t = t + a
        m, g = mlp(blk, layer_norm(t, blk["ln2_g"], blk["ln2_b"]), var, use_pallas)
        t = t + m
        if g is not None:
            gates.append(g)

    t = layer_norm(t, params["norm_g"], params["norm_b"])
    pooled = t.mean(axis=1)
    logits = pooled @ params["head_w"] + params["head_b"]
    return logits, {"gates": gates}


# --------------------------------------------------------------------------
# Latency-aware load-balancing loss (Eq. 4)
# --------------------------------------------------------------------------


def scv(values):
    """Squared coefficient of variation of a vector."""
    mu = values.mean()
    return ((values - mu) ** 2).mean() / (mu**2 + 1e-9)


def ll_loss(gates, alphas, noise_sigma: float = 0.1):
    """Latency-aware importance + load losses over one MoE layer's gates.

    gates: (B, N, 2) softmax router outputs; alphas: (2,) latency
    coefficients α_i = Lat_i / Σ_j Lat_j. Minimizing SCV({α_i S_i}) drives
    S_i ∝ 1/α_i — faster experts receive more tokens (paper §4.2).

    The load term uses the differentiable noisy-top-1 proxy of [48]:
    q_i(x) = P(p_i + ε ≥ p_j) ≈ sigmoid((p_i − p_j)/σ).
    """
    p = gates.reshape(-1, gates.shape[-1])  # (T, 2)
    importance = (alphas * p.sum(axis=0))
    diff = (p[:, 0] - p[:, 1]) / noise_sigma
    q0 = jax.nn.sigmoid(diff)
    load = alphas * jnp.stack([q0.sum(), (1.0 - q0).sum()])
    return scv(importance) + scv(load)


def classification_loss(params, x, y, cfg, var, alphas, lam: float = 0.01):
    """L_CLS + λ·(L_IMP + L_LOAD) — the paper's total objective."""
    logits, aux = forward(params, x, cfg, var, use_pallas=False)
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    balance = 0.0
    for g in aux["gates"]:
        balance = balance + ll_loss(g, alphas)
    return ce + lam * balance, aux
