"""LRA-style long-sequence models (Table 11, Appendix D).

Synthetic Long-Range-Arena substitution (DESIGN.md §2): four sequence tasks
whose labels depend on long-range token statistics, plus the paper's
comparator attention families implemented for real:

- ``transformer`` — full softmax MSA (quadratic),
- ``reformer``    — block-local attention (LSH-bucket stand-in),
- ``linformer``   — low-rank projection of K/V along the sequence,
- ``performer``   — random-feature (FAVOR-style, ReLU features) linear attn,
- ``shiftadd``    — OUR model: binarized Hamming linear attention (MatAdd)
  + shift-reparameterized MLPs.

Tasks (vocab 16, seq len configurable):
- ``text``      — does pattern token-pair (3,7) occur more than τ times?
- ``listops``   — (max digit + min digit) of the digit subsequence, mod 4
- ``retrieval`` — first and second half have equal token multisets?
- ``image``     — flattened synthetic shape image (quantized to 16 gray
  levels); label = shape class.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import data as D
from .kernels import ref

VOCAB = 16


@dataclasses.dataclass(frozen=True)
class LraConfig:
    seq: int = 128
    dim: int = 32
    depth: int = 2
    heads: int = 2
    classes: int = 4
    lowrank: int = 16  # linformer projection size
    feats: int = 16  # performer feature count


LRA_CFG = LraConfig()
LRA_ATTNS = ["transformer", "reformer", "linformer", "performer", "shiftadd"]
LRA_TASKS = ["text", "listops", "retrieval", "image"]


# ------------------------------------------------------------------ tasks


def gen_task(task: str, seed: int, n: int, cfg: LraConfig = LRA_CFG):
    """Generate ``n`` (sequence, label) pairs for ``task``."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, cfg.seq), np.int32)
    ys = np.zeros((n,), np.int32)
    for i in range(n):
        if task == "text":
            s = rng.integers(0, VOCAB, cfg.seq)
            # plant between 0 and 7 (3,7) bigrams; label = count > 3
            cnt = int(rng.integers(0, 8))
            for _ in range(cnt):
                p = int(rng.integers(0, cfg.seq - 1))
                s[p], s[p + 1] = 3, 7
            real = int(np.sum((s[:-1] == 3) & (s[1:] == 7)))
            xs[i], ys[i] = s, int(real > 3)
        elif task == "listops":
            # label = (first digit + last digit) mod 4 — long-range pairing
            # (max+min of a long uniform stream is degenerate).
            s = rng.integers(0, VOCAB, cfg.seq)
            digits = s[s < 10]
            val = (int(digits[0]) + int(digits[-1])) % 4 if len(digits) else 0
            xs[i], ys[i] = s, val
        elif task == "retrieval":
            half = cfg.seq // 2
            a = rng.integers(0, VOCAB, half)
            if rng.uniform() < 0.5:
                b = a.copy()
                rng.shuffle(b)
                lab = 1
            else:
                b = rng.integers(0, VOCAB, half)
                lab = int(np.array_equal(np.sort(a), np.sort(b)))
            xs[i] = np.concatenate([a, b])
            ys[i] = lab
        elif task == "image":
            side = int(cfg.seq**0.5)  # floor; trailing tokens zero-padded
            img, lab = D.gen_image(seed * 1000 + i)
            # Downsample to side×side grayscale, quantize to VOCAB levels.
            stride = max(D.IMG // side, 1)
            g = img[::stride, ::stride, :].mean(axis=-1)[:side, :side]
            flat = np.clip((g * VOCAB).astype(np.int32), 0, VOCAB - 1).reshape(-1)
            xs[i, : flat.size] = flat
            ys[i] = lab % cfg.classes
        else:
            raise ValueError(task)
    return xs, ys


# ------------------------------------------------------------------ model


def init_lra_params(key, cfg: LraConfig = LRA_CFG):
    keys = iter(jax.random.split(key, 8 + 16 * cfg.depth))

    def dense(fi, fo):
        return (2.0 / (fi + fo)) ** 0.5 * jax.random.normal(next(keys), (fi, fo))

    p = {
        "emb": 0.5 * jax.random.normal(next(keys), (VOCAB, cfg.dim)),
        "pos": 0.02 * jax.random.normal(next(keys), (cfg.seq, cfg.dim)),
        "head_w": dense(cfg.dim, cfg.classes),
        "head_b": jnp.zeros((cfg.classes,)),
        "linf_e": dense(cfg.seq, cfg.lowrank),  # linformer K/V projection
        "perf_w": jax.random.normal(next(keys), (cfg.dim // cfg.heads, cfg.feats)),
        "blocks": [],
    }
    h = cfg.dim * 2
    for _ in range(cfg.depth):
        p["blocks"].append(
            {
                "ln1_g": jnp.ones((cfg.dim,)),
                "ln1_b": jnp.zeros((cfg.dim,)),
                "ln2_g": jnp.ones((cfg.dim,)),
                "ln2_b": jnp.zeros((cfg.dim,)),
                "wq": dense(cfg.dim, cfg.dim),
                "wk": dense(cfg.dim, cfg.dim),
                "wv": dense(cfg.dim, cfg.dim),
                "wo": dense(cfg.dim, cfg.dim),
                "w1": dense(cfg.dim, h),
                "b1": jnp.zeros((h,)),
                "w2": dense(h, cfg.dim),
                "b2": jnp.zeros((cfg.dim,)),
            }
        )
    return p


def _attend(kind, qh, kh, vh, params, cfg):
    """(B,H,N,hd) q/k/v → (B,H,N,hd) per attention family."""
    if kind == "transformer":
        return jax.vmap(jax.vmap(ref.softmax_attn_ref))(qh, kh, vh)
    if kind == "reformer":
        # Block-local attention with block 32 (LSH-bucket stand-in).
        b, h, n, d = qh.shape
        blk = 32
        q = qh.reshape(b, h, n // blk, blk, d)
        k = kh.reshape(b, h, n // blk, blk, d)
        v = vh.reshape(b, h, n // blk, blk, d)
        out = jax.vmap(jax.vmap(jax.vmap(ref.softmax_attn_ref)))(q, k, v)
        return out.reshape(b, h, n, d)
    if kind == "linformer":
        e = params["linf_e"]  # (N, k)
        ke = jnp.einsum("bhnd,nk->bhkd", kh, e)
        ve = jnp.einsum("bhnd,nk->bhkd", vh, e)
        return jax.vmap(jax.vmap(ref.softmax_attn_ref))(qh, ke, ve)
    if kind == "performer":
        w = params["perf_w"]  # (hd, m)
        fq = jax.nn.relu(jnp.einsum("bhnd,dm->bhnm", qh, w)) + 1e-3
        fk = jax.nn.relu(jnp.einsum("bhnd,dm->bhnm", kh, w)) + 1e-3
        kv = jnp.einsum("bhnm,bhnd->bhmd", fk, vh)
        z = fk.sum(axis=2)
        num = jnp.einsum("bhnm,bhmd->bhnd", fq, kv)
        den = jnp.einsum("bhnm,bhm->bhn", fq, z)[..., None]
        return num / (den + 1e-6)
    if kind == "shiftadd":
        qb, kb = M.ste_sign(qh), M.ste_sign(kh)
        return jax.vmap(jax.vmap(ref.linattn_ref))(qb, kb, vh)
    raise ValueError(kind)


def lra_forward(params, tokens, attn: str, cfg: LraConfig = LRA_CFG):
    """tokens (B,N) int32 → logits (B, classes)."""
    b, n = tokens.shape
    shift_mlp = attn == "shiftadd"
    t = params["emb"][tokens] + params["pos"][None, :, :]
    hd = cfg.dim // cfg.heads
    for blk in params["blocks"]:
        u = M.layer_norm(t, blk["ln1_g"], blk["ln1_b"])
        q, k, v = u @ blk["wq"], u @ blk["wk"], u @ blk["wv"]

        def split(z):
            return z.reshape(b, n, cfg.heads, hd).transpose(0, 2, 1, 3)

        oh = _attend(attn, split(q), split(k), split(v), params, cfg)
        a = oh.transpose(0, 2, 1, 3).reshape(b, n, cfg.dim)
        t = t + a @ blk["wo"]
        u = M.layer_norm(t, blk["ln2_g"], blk["ln2_b"])
        w1 = M.ste_pow2(blk["w1"]) if shift_mlp else blk["w1"]
        w2 = M.ste_pow2(blk["w2"]) if shift_mlp else blk["w2"]
        t = t + (jax.nn.relu(u @ w1 + blk["b1"]) @ w2 + blk["b2"])
    pooled = t.mean(axis=1)
    return pooled @ params["head_w"] + params["head_b"]


def build_artifacts(w, quick: bool):
    from .params_io import load_params_lra

    attns = LRA_ATTNS if not quick else ["transformer", "shiftadd"]
    for attn in attns:
        params = load_params_lra("text", attn)

        def fwd(tok, params=params, attn=attn):
            return (lra_forward(params, tok, attn),)

        w.add(
            f"lra_{attn}_bs1",
            fwd,
            (jax.ShapeDtypeStruct((1, LRA_CFG.seq), jnp.int32),),
            kind="lra",
            attn=attn,
            seq=LRA_CFG.seq,
        )
