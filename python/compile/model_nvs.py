"""GNT-style ray transformer for the 3D novel-view-synthesis task (Table 5).

LLFF substitution (DESIGN.md §2): analytic scenes — colored spheres over a
ground plane under a procedural sky — rendered exactly by ray casting give
ground-truth images; the "GNT" model is a per-scene ray transformer that maps
positional encodings of sample points along a ray to an RGB color via
attention over the points (the paper's ray transformer), trained to fit the
scene (NeRF-style). ShiftAddViT variants apply the same reparameterizations:

- ``add``   — binarized Q/K in the ray attention (MatAdd accumulations);
  note Table 5 keeps MSA order (no linear attention) for NVS, so binarized
  attention here stays softmax-free Hamming-weighted like the 2D path,
- ``shift`` — attention Linears and/or MLPs → s·2^P weights,
- ``moe``   — MLPs → Mult/Shift experts with point-level routing.

The Rust side mirrors the scene generator (rust/src/nvs/scenes.rs) so the
renderer can score PSNR/SSIM against the same ground truth.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels import ref

# ----------------------------------------------------------------- scenes

# Each scene: list of spheres (cx, cy, cz, r, colr, colg, colb) + plane color.
# Analogues of the LLFF scene names.
SCENES: Dict[str, Dict[str, Any]] = {}


def _mk_scene(name: str, seed: int, n_spheres: int):
    rng = np.random.default_rng(seed)
    spheres = []
    for _ in range(n_spheres):
        spheres.append(
            [
                float(rng.uniform(-1.5, 1.5)),  # cx
                float(rng.uniform(-0.2, 1.2)),  # cy
                float(rng.uniform(2.5, 5.0)),  # cz
                float(rng.uniform(0.3, 0.7)),  # r
                float(rng.uniform(0.2, 1.0)),
                float(rng.uniform(0.2, 1.0)),
                float(rng.uniform(0.2, 1.0)),
            ]
        )
    SCENES[name] = {
        "spheres": spheres,
        "plane_col": [0.35, 0.3, 0.25],
        "sky": [0.5, 0.6, 0.8],
    }


for i, nm in enumerate(
    ["room", "fern", "leaves", "fortress", "orchids", "flower", "trex", "horns"]
):
    _mk_scene(nm, 100 + i, 3 + (i % 3))


def ray_trace(scene, origins, dirs):
    """Exact reference render: (R,3) origins/dirs → (R,3) RGB in [0,1]."""
    o = np.asarray(origins, np.float64)
    d = np.asarray(dirs, np.float64)
    d = d / np.linalg.norm(d, axis=-1, keepdims=True)
    r_count = o.shape[0]
    col = np.zeros((r_count, 3))
    tmin = np.full((r_count,), np.inf)
    # sky background modulated by ray elevation
    sky = np.asarray(scene["sky"])
    col[:] = sky[None, :] * (0.6 + 0.4 * np.clip(d[:, 1:2], 0, 1))
    # ground plane y = -0.5
    denom = d[:, 1]
    tp = np.where(np.abs(denom) > 1e-6, (-0.5 - o[:, 1]) / denom, np.inf)
    hit_p = (tp > 1e-3) & (tp < tmin)
    px = o[:, 0] + tp * d[:, 0]
    pz = o[:, 2] + tp * d[:, 2]
    checker = ((np.floor(px) + np.floor(pz)) % 2 == 0).astype(np.float64)
    pc = np.asarray(scene["plane_col"])
    plane_rgb = pc[None, :] * (0.7 + 0.3 * checker[:, None])
    col = np.where(hit_p[:, None], plane_rgb, col)
    tmin = np.where(hit_p, tp, tmin)
    for s in scene["spheres"]:
        c = np.asarray(s[:3])
        r = s[3]
        rgb = np.asarray(s[4:7])
        oc = o - c[None, :]
        bq = np.einsum("rd,rd->r", oc, d)
        cq = np.einsum("rd,rd->r", oc, oc) - r * r
        disc = bq * bq - cq
        ts = np.where(disc > 0, -bq - np.sqrt(np.maximum(disc, 0)), np.inf)
        hit = (ts > 1e-3) & (ts < tmin)
        # Lambertian shade with a fixed light.
        p = o + ts[:, None] * d
        nrm = (p - c[None, :]) / r
        light = np.asarray([0.5, 0.8, -0.3])
        light = light / np.linalg.norm(light)
        lam = np.clip(np.einsum("rd,d->r", nrm, light), 0.1, 1.0)
        col = np.where(hit[:, None], rgb[None, :] * lam[:, None], col)
        tmin = np.where(hit, ts, tmin)
    return col.astype(np.float32)


def camera_rays(img: int, pose_angle: float = 0.0):
    """Pinhole camera at origin looking +z, rotated by pose_angle around y."""
    ys, xs = np.meshgrid(np.arange(img), np.arange(img), indexing="ij")
    u = (xs + 0.5) / img * 2 - 1
    v = 1 - (ys + 0.5) / img * 2
    dirs = np.stack([u, v, np.ones_like(u)], axis=-1).reshape(-1, 3)
    ca, sa = np.cos(pose_angle), np.sin(pose_angle)
    rot = np.asarray([[ca, 0, sa], [0, 1, 0], [-sa, 0, ca]])
    dirs = dirs @ rot.T
    origins = np.zeros_like(dirs)
    return origins.astype(np.float32), dirs.astype(np.float32)


# ------------------------------------------------------------------ model


@dataclasses.dataclass(frozen=True)
class NvsConfig:
    name: str = "gnt_tiny"
    points: int = 16  # samples per ray
    pe_levels: int = 4  # positional-encoding octaves
    dim: int = 32
    depth: int = 2
    heads: int = 2
    t_near: float = 0.5
    t_far: float = 6.0

    @property
    def in_dim(self) -> int:
        return 3 * 2 * self.pe_levels + 3  # PE(xyz) + dir


NVS_CFG = NvsConfig()


@dataclasses.dataclass(frozen=True)
class NvsVariant:
    """attn: 'msa' | 'add'; linears/mlp: 'mult' | 'shift' | 'moe' (mlp only)."""

    attn: str = "msa"
    lin: str = "mult"
    mlp: str = "mult"

    def tag(self):
        return f"{self.attn}_{self.lin}_{self.mlp}"


NVS_VARIANTS = {
    "gnt": NvsVariant(),
    "add": NvsVariant(attn="add"),
    "add_shift_both": NvsVariant(attn="add", lin="shift", mlp="shift"),
    "add_shiftattn_moe": NvsVariant(attn="add", lin="shift", mlp="moe"),
    "shift_both": NvsVariant(attn="msa", lin="shift", mlp="shift"),
}


def init_nvs_params(key, cfg: NvsConfig = NVS_CFG):
    keys = iter(jax.random.split(key, 8 + 24 * cfg.depth))

    def dense(fi, fo):
        return (2.0 / (fi + fo)) ** 0.5 * jax.random.normal(next(keys), (fi, fo))

    p = {
        "in_w": dense(cfg.in_dim, cfg.dim),
        "in_b": jnp.zeros((cfg.dim,)),
        "out_w": dense(cfg.dim, 3),
        "out_b": jnp.zeros((3,)),
        "blocks": [],
    }
    h = cfg.dim * 2
    for _ in range(cfg.depth):
        p["blocks"].append(
            {
                "ln1_g": jnp.ones((cfg.dim,)),
                "ln1_b": jnp.zeros((cfg.dim,)),
                "ln2_g": jnp.ones((cfg.dim,)),
                "ln2_b": jnp.zeros((cfg.dim,)),
                "wq": dense(cfg.dim, cfg.dim),
                "wk": dense(cfg.dim, cfg.dim),
                "wv": dense(cfg.dim, cfg.dim),
                "wo": dense(cfg.dim, cfg.dim),
                "w1": dense(cfg.dim, h),
                "b1": jnp.zeros((h,)),
                "w2": dense(h, cfg.dim),
                "b2": jnp.zeros((cfg.dim,)),
                "w1s": dense(cfg.dim, h),
                "b1s": jnp.zeros((h,)),
                "w2s": dense(h, cfg.dim),
                "b2s": jnp.zeros((cfg.dim,)),
                "gate_w": 0.02 * jax.random.normal(next(keys), (cfg.dim, 2)),
            }
        )
    return p


def _lin(x, w, kind):
    if kind == "shift":
        return x @ M.ste_pow2(w)
    return x @ w


def posenc(pts, levels):
    feats = [pts]
    del feats[:]  # PE only; dir appended separately
    out = []
    for l in range(levels):
        out.append(jnp.sin(pts * (2.0**l) * np.pi))
        out.append(jnp.cos(pts * (2.0**l) * np.pi))
    return jnp.concatenate(out, axis=-1)


def nvs_forward(params, origins, dirs, var: NvsVariant, cfg: NvsConfig = NVS_CFG):
    """(R,3) origins/dirs → (R,3) RGB. Attention runs *across ray samples*."""
    r = origins.shape[0]
    ts = jnp.linspace(cfg.t_near, cfg.t_far, cfg.points)
    d = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    pts = origins[:, None, :] + ts[None, :, None] * d[:, None, :]  # (R,P,3)
    feat = jnp.concatenate(
        [posenc(pts / cfg.t_far, cfg.pe_levels), jnp.broadcast_to(d[:, None, :], pts.shape)],
        axis=-1,
    )
    t = feat @ params["in_w"] + params["in_b"]  # (R,P,dim)

    hd = cfg.dim // cfg.heads
    for blk in params["blocks"]:
        u = M.layer_norm(t, blk["ln1_g"], blk["ln1_b"])
        q = _lin(u, blk["wq"], var.lin)
        k = _lin(u, blk["wk"], var.lin)
        v = _lin(u, blk["wv"], var.lin)

        def split(z):  # (R,P,dim) -> (R,H,P,hd)
            return z.reshape(r, cfg.points, cfg.heads, hd).transpose(0, 2, 1, 3)

        qh, kh, vh = split(q), split(k), split(v)
        if var.attn == "msa":
            oh = jax.vmap(jax.vmap(ref.softmax_attn_ref))(qh, kh, vh)
        else:  # 'add' — binarized Hamming attention (quadratic form is fine,
            # P=16 points; the *adds-not-mults* property is what carries over)
            qb, kb = M.ste_sign(qh), M.ste_sign(kh)
            oh = jax.vmap(jax.vmap(ref.linattn_ref))(qb, kb, vh)
        a = oh.transpose(0, 2, 1, 3).reshape(r, cfg.points, cfg.dim)
        t = t + _lin(a, blk["wo"], var.lin)

        u = M.layer_norm(t, blk["ln2_g"], blk["ln2_b"])
        if var.mlp == "moe":
            flat = u.reshape(r * cfg.points, cfg.dim)
            pgate = jax.nn.softmax(flat @ blk["gate_w"], axis=-1)
            mw = (pgate[:, 0:1] >= pgate[:, 1:2]).astype(flat.dtype)
            gv = jnp.where(mw > 0, pgate[:, 0:1], pgate[:, 1:2])
            y_m = jax.nn.relu(flat @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
            y_s = (
                jax.nn.relu(flat @ M.ste_pow2(blk["w1s"]) + blk["b1s"])
                @ M.ste_pow2(blk["w2s"])
                + blk["b2s"]
            )
            y = (gv * (mw * y_m + (1 - mw) * y_s)).reshape(r, cfg.points, cfg.dim)
        elif var.mlp == "shift":
            y = (
                jax.nn.relu(u @ M.ste_pow2(blk["w1s"]) + blk["b1s"]) @ M.ste_pow2(blk["w2s"])
                + blk["b2s"]
            )
        else:
            y = jax.nn.relu(u @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
        t = t + y

    # Aggregate over ray samples (mean-pool "ray transformer" readout).
    pooled = t.mean(axis=1)
    rgb = jax.nn.sigmoid(pooled @ params["out_w"] + params["out_b"])
    return rgb


def build_artifacts(w, quick: bool):
    """Lower the NVS forward for each variant (ray-batched, R=256)."""
    from .params_io import load_params_nvs

    # Export scene definitions so the Rust renderer ray-traces identical
    # ground truth (rust/src/nvs/scenes.rs parses this).
    w.manifest["nvs_scenes"] = {
        name: {
            "spheres": sc["spheres"],
            "plane_col": sc["plane_col"],
            "sky": sc["sky"],
        }
        for name, sc in SCENES.items()
    }

    rays = 256
    variants = list(NVS_VARIANTS) if not quick else ["gnt", "add_shiftattn_moe"]
    for vname in variants:
        var = NVS_VARIANTS[vname]
        params = load_params_nvs("orchids", vname)

        def fwd(o, d, params=params, var=var):
            return (nvs_forward(params, o, d, var),)

        w.add(
            f"nvs_{vname}_r{rays}",
            fwd,
            (
                jax.ShapeDtypeStruct((rays, 3), jnp.float32),
                jax.ShapeDtypeStruct((rays, 3), jnp.float32),
            ),
            kind="nvs",
            variant=vname,
            rays=rays,
        )
