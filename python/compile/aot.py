"""AOT compile path: lower every model variant to HLO *text* artifacts.

Run once via ``make artifacts``; Python never appears on the request path.

Interchange format is HLO text, NOT ``lowered.compiler_ir("hlo")`` protos nor
``.serialize()``: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids
which the Rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifact families written to ``artifacts/``:

- ``cls_<model>_<variant>_bs<B>.hlo.txt`` — whole-model classification
  forward, weights baked as constants (latency/throughput benches; Tables
  3/4/6/12).
- ``pallas_<model>_<variant>_bs1.hlo.txt`` — same forward but routed through
  the L1 Pallas kernels (interpret mode), proving the three layers compose;
  executed by the Rust integration tests.
- ``serve_*`` — the pipeline-decomposed serving model for the L3
  coordinator's real sparse MoE dispatch: stem, per-block attention,
  per-block pre-MLP (LN + router gates), per-expert MLPs at several token
  buckets, classifier head.
- ``nvs_*`` / ``lra_*`` — GNT-style ray transformer and LRA sequence models
  (Tables 5, 11).
- ``manifest.json`` — shapes/dtypes and the serving topology for Rust.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import model_nvs as NVS
from . import model_lra as LRA
from .params_io import load_params, trained_path


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default printer elides
    # baked weights as `constant({...})`, which the Rust-side text parser
    # silently fills with zeros — every model would run with zero weights.
    return comp.as_hlo_text(print_large_constants=True)


def lower_fn(fn, example_args):
    return to_hlo_text(jax.jit(fn).lower(*example_args))


class ArtifactWriter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"models": {}, "serve": {}, "meta": {}}
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name: str, fn, example_args, **meta):
        t0 = time.time()
        text = lower_fn(fn, example_args)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        self.manifest["models"][name] = {
            "path": path,
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
            ],
            **meta,
        }
        print(f"  lowered {name:48s} {len(text)//1024:5d} KiB  {time.time()-t0:.1f}s")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote manifest with {len(self.manifest['models'])} artifacts")


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Variants lowered for the breakdown tables (4/6): one per table row family.
BENCH_VARIANTS = [
    "msa",
    "linear",
    "add_ksh",
    "add_quant",
    "add_ksh_shiftattn",
    "add_quant_shift_both",
    "add_ksh_moe_both",
    "add_quant_moe_both",
]


def build_classifiers(w: ArtifactWriter, models, batches, quick: bool):
    for mname in models:
        cfg = M.MODELS[mname]
        variants = BENCH_VARIANTS if not quick else ["msa", "add_quant_moe_both"]
        for vname in variants:
            var = M.VARIANTS[vname]
            params = load_params(mname, vname, cfg)

            def fwd(x, params=params, cfg=cfg, var=var):
                logits, _ = M.forward(params, x, cfg, var, use_pallas=False)
                return (logits,)

            for bs in batches:
                w.add(
                    f"cls_{mname}_{vname}_bs{bs}",
                    fwd,
                    (spec(bs, cfg.img, cfg.img, 3),),
                    kind="classifier",
                    model=mname,
                    variant=vname,
                    batch=bs,
                )


def build_pallas_proof(w: ArtifactWriter, mname="pvtv2_b0", vname="add_quant_moe_both"):
    """Lower the pallas-kernel path of one full model (L1∘L2∘L3 composition)."""
    cfg = M.MODELS[mname]
    var = M.VARIANTS[vname]
    params = load_params(mname, vname, cfg)

    def fwd(x):
        logits, _ = M.forward(params, x, cfg, var, use_pallas=True)
        return (logits,)

    w.add(
        f"pallas_{mname}_{vname}_bs1",
        fwd,
        (spec(1, cfg.img, cfg.img, 3),),
        kind="classifier_pallas",
        model=mname,
        variant=vname,
        batch=1,
    )


def build_serving(w: ArtifactWriter, mname: str, vname: str, quick: bool):
    """Pipeline-decomposed serving model (real sparse MoE dispatch in Rust)."""
    cfg = M.MODELS[mname]
    var = M.VARIANTS[vname]
    assert var.mlp == "moe", "serving decomposition expects the MoE variant"
    params = load_params(mname, vname, cfg)
    grid = cfg.img // cfg.patch
    n, d = cfg.tokens, cfg.dim
    batch_buckets = [1, 2, 4, 8] if not quick else [1, 4]
    token_buckets = [64, 128, 256, 512] if not quick else [64, 256]

    def stem(x):
        b = x.shape[0]
        ph = x.reshape(b, grid, cfg.patch, grid, cfg.patch, 3)
        ph = ph.transpose(0, 1, 3, 2, 4, 5).reshape(b, grid * grid, -1)
        return (ph @ params["embed_w"] + params["embed_b"] + params["pos"],)

    def blk_attn(t, blk):
        M.params_global = params
        u = M.layer_norm(t, blk["ln1_g"], blk["ln1_b"])
        return (t + M.attention(blk, u, cfg, var, False, grid),)

    def blk_premlp(t, blk):
        """LN2 + router gates — everything the coordinator needs to dispatch."""
        u = M.layer_norm(t, blk["ln2_g"], blk["ln2_b"])
        gates = jax.nn.softmax(u @ blk["gate_w"], axis=-1)
        return (u, gates)

    def expert_mult(u, blk):
        h = jax.nn.relu(u @ blk["w1"] + blk["b1"])
        return (h @ blk["w2"] + blk["b2"],)

    def expert_shift(u, blk):
        from .kernels import ref

        w1 = ref.pow2_dequantize(*ref.pow2_quantize(blk["w1s"]))
        w2 = ref.pow2_dequantize(*ref.pow2_quantize(blk["w2s"]))
        h = jax.nn.relu(u @ w1 + blk["b1s"])
        return (h @ w2 + blk["b2s"],)

    def head(t):
        u = M.layer_norm(t, params["norm_g"], params["norm_b"])
        return (u.mean(axis=1) @ params["head_w"] + params["head_b"],)

    for bs in batch_buckets:
        w.add(f"serve_stem_bs{bs}", stem, (spec(bs, cfg.img, cfg.img, 3),), kind="serve_stem", batch=bs)
        w.add(f"serve_head_bs{bs}", head, (spec(bs, n, d),), kind="serve_head", batch=bs)

    blocks_meta = []
    for i, blk in enumerate(params["blocks"]):
        for bs in batch_buckets:
            w.add(
                f"serve_blk{i}_attn_bs{bs}",
                lambda t, blk=blk: blk_attn(t, blk),
                (spec(bs, n, d),),
                kind="serve_attn",
                block=i,
                batch=bs,
            )
            w.add(
                f"serve_blk{i}_premlp_bs{bs}",
                lambda t, blk=blk: blk_premlp(t, blk),
                (spec(bs, n, d),),
                kind="serve_premlp",
                block=i,
                batch=bs,
            )
        for nb in token_buckets:
            w.add(
                f"serve_expert_mult_blk{i}_n{nb}",
                lambda u, blk=blk: expert_mult(u, blk),
                (spec(nb, d),),
                kind="serve_expert",
                expert="mult",
                block=i,
                tokens=nb,
            )
            w.add(
                f"serve_expert_shift_blk{i}_n{nb}",
                lambda u, blk=blk: expert_shift(u, blk),
                (spec(nb, d),),
                kind="serve_expert",
                expert="shift",
                block=i,
                tokens=nb,
            )
        blocks_meta.append({"block": i, "moe": True})

    w.manifest["serve"] = {
        "model": mname,
        "variant": vname,
        "img": cfg.img,
        "patch": cfg.patch,
        "tokens": n,
        "dim": d,
        "depth": cfg.depth,
        "num_classes": cfg.num_classes,
        "batch_buckets": batch_buckets,
        "token_buckets": token_buckets,
        "blocks": blocks_meta,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/manifest.json")
    ap.add_argument("--quick", action="store_true", help="small artifact set for CI")
    ap.add_argument(
        "--models",
        default="pvtv2_b0,pvtv1_t,pvtv2_b1,pvtv2_b2,deit_t",
        help="comma-separated classifier configs to lower",
    )
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out))

    w = ArtifactWriter(out_dir)
    models = args.models.split(",") if not args.quick else ["pvtv2_b0"]
    print("== classifiers ==")
    build_classifiers(w, models, batches=[1, 32] if not args.quick else [1], quick=args.quick)
    print("== pallas composition proof ==")
    build_pallas_proof(w)
    print("== serving pipeline ==")
    build_serving(w, "pvtv2_b0", "add_quant_moe_both", quick=args.quick)
    print("== NVS (GNT-style ray transformer) ==")
    NVS.build_artifacts(w, quick=args.quick)
    print("== LRA sequence models ==")
    LRA.build_artifacts(w, quick=args.quick)
    w.manifest["meta"] = {
        "jax": jax.__version__,
        "quick": args.quick,
        "note": "weights are trained if python/trained/*.npz existed at build time, else seeded-random",
    }
    w.finish()


if __name__ == "__main__":
    main()
