"""Synthetic "shapes" classification dataset (ImageNet substitute).

The paper's MoE hypothesis is that *object* tokens need the powerful Mult.
expert while *background* tokens can be handled by the cheap Shift expert.
This generator preserves exactly that structure: each image is a textured
background plus a single filled shape whose class is the label. Token-level
object/background separability is therefore real, which is what the router
must learn (Fig. 6/9 reproduction).

The generator is mirrored bit-for-bit in Rust (``rust/src/data/synth_images.rs``)
so the serving path scores accuracy on the *same* distribution the model was
trained on. Both sides use the same xorshift32 PRNG and integer rasterizer —
keep the two implementations in sync.
"""

from __future__ import annotations

import numpy as np

IMG = 32  # image side
NUM_CLASSES = 8

_SHAPES = [
    "circle",
    "square",
    "triangle",
    "cross",
    "ring",
    "diamond",
    "hbar",
    "vbar",
]


def xorshift32(state: int) -> int:
    """One step of xorshift32 (matches rust/src/util/rng.rs)."""
    state &= 0xFFFFFFFF
    state ^= (state << 13) & 0xFFFFFFFF
    state ^= state >> 17
    state ^= (state << 5) & 0xFFFFFFFF
    return state & 0xFFFFFFFF


class Rng:
    """Tiny deterministic PRNG shared with the Rust side."""

    def __init__(self, seed: int):
        self.state = (seed | 1) & 0xFFFFFFFF

    def next_u32(self) -> int:
        self.state = xorshift32(self.state)
        return self.state

    def uniform(self) -> float:
        """Uniform in [0, 1) with 24 bits of entropy (f32-exact)."""
        return (self.next_u32() >> 8) / float(1 << 24)

    def randint(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi)."""
        return lo + self.next_u32() % (hi - lo)


def _inside(shape_id: int, dx: int, dy: int, r: int) -> bool:
    """Integer point-in-shape test; dx/dy are offsets from the center."""
    ax, ay = abs(dx), abs(dy)
    if shape_id == 0:  # circle
        return dx * dx + dy * dy <= r * r
    if shape_id == 1:  # square
        return ax <= r and ay <= r
    if shape_id == 2:  # triangle (upward)
        return dy >= -r and dy <= r and ax * 2 <= (r - dy)
    if shape_id == 3:  # cross
        return (ax <= r // 2 and ay <= r) or (ay <= r // 2 and ax <= r)
    if shape_id == 4:  # ring
        d2 = dx * dx + dy * dy
        inner = max(r - 2, 1)
        return inner * inner <= d2 <= r * r
    if shape_id == 5:  # diamond
        return ax + ay <= r
    if shape_id == 6:  # horizontal bar
        return ay <= max(r // 3, 1) and ax <= r
    if shape_id == 7:  # vertical bar
        return ax <= max(r // 3, 1) and ay <= r
    raise ValueError(shape_id)


def gen_image(seed: int) -> tuple[np.ndarray, int]:
    """Generate one (IMG, IMG, 3) float32 image in [0,1] and its label.

    Deterministic in ``seed``. Background = per-8x8-cell checkerboard shade +
    uniform noise; foreground = filled shape with a distinct color.
    """
    rng = Rng(seed)
    label = rng.randint(0, NUM_CLASSES)
    img = np.zeros((IMG, IMG, 3), dtype=np.float32)

    base = 0.2 + 0.3 * rng.uniform()
    for y in range(IMG):
        for x in range(IMG):
            checker = 0.1 if ((x // 8) + (y // 8)) % 2 == 0 else 0.0
            noise = 0.08 * rng.uniform()
            v = base + checker + noise
            img[y, x, 0] = v
            img[y, x, 1] = v
            img[y, x, 2] = v

    # Foreground shape: random center, radius, saturated color.
    r = rng.randint(5, 10)
    cx = rng.randint(r + 1, IMG - r - 1)
    cy = rng.randint(r + 1, IMG - r - 1)
    col = (
        0.55 + 0.45 * rng.uniform(),
        0.15 * rng.uniform(),
        0.55 + 0.45 * rng.uniform(),
    )
    for y in range(cy - r, cy + r + 1):
        for x in range(cx - r, cx + r + 1):
            if 0 <= x < IMG and 0 <= y < IMG and _inside(label, x - cx, y - cy, r):
                img[y, x, 0] = col[0]
                img[y, x, 1] = col[1]
                img[y, x, 2] = col[2]
    return img, label


def gen_batch(seed0: int, n: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` images with seeds ``seed0 .. seed0+n-1``."""
    xs = np.zeros((n, IMG, IMG, 3), dtype=np.float32)
    ys = np.zeros((n,), dtype=np.int32)
    for i in range(n):
        xs[i], ys[i] = gen_image(seed0 + i)
    return xs, ys


def object_mask(seed: int, patch: int = 4) -> np.ndarray:
    """Ground-truth token-level object mask (for router-dispatch validation).

    Returns a bool array of shape (IMG//patch, IMG//patch): True where the
    patch overlaps the foreground shape.
    """
    rng = Rng(seed)
    label = rng.randint(0, NUM_CLASSES)
    # Burn the same PRNG draws as gen_image's background loop.
    rng.uniform()
    for _ in range(IMG * IMG):
        rng.uniform()
    r = rng.randint(5, 10)
    cx = rng.randint(r + 1, IMG - r - 1)
    cy = rng.randint(r + 1, IMG - r - 1)
    g = IMG // patch
    mask = np.zeros((g, g), dtype=bool)
    for y in range(cy - r, cy + r + 1):
        for x in range(cx - r, cx + r + 1):
            if 0 <= x < IMG and 0 <= y < IMG and _inside(label, x - cx, y - cy, r):
                mask[y // patch, x // patch] = True
    return mask
