"""Dense-masked 2-expert MoE MLP — the AOT-friendly lowering of Fig. 1(c).

At *serving* time the Rust coordinator performs real sparse dispatch (tokens
are physically partitioned between a Mult-expert executable and a
Shift-expert executable). At *lowering/training* time shapes must be static,
so this kernel computes both experts for every token block and combines with
the hard top-1 gate — numerically identical to sparse dispatch (the paper's
G(x) = p_i · 1{p_i ≥ p_j} routing), just not faster. See DESIGN.md §3.

Grid: one program per token block; all weights resident (tiny-d models), so
the only HBM traffic per step is the token block itself.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_kernel(
    x_ref,
    gate_ref,
    w1m_ref,
    b1m_ref,
    w2m_ref,
    b2m_ref,
    s1_ref,
    p1_ref,
    b1s_ref,
    s2_ref,
    p2_ref,
    b2s_ref,
    o_ref,
):
    x = x_ref[...]  # (bt, d)

    # Router: softmax over 2 experts, hard top-1 scaled by its gate value.
    logits = x @ gate_ref[...]  # (bt, 2)
    logits = logits - logits.max(axis=-1, keepdims=True)
    e = jnp.exp(logits)
    pgate = e / e.sum(axis=-1, keepdims=True)
    mult_wins = (pgate[:, 0:1] >= pgate[:, 1:2]).astype(x.dtype)
    gval = jnp.where(mult_wins > 0, pgate[:, 0:1], pgate[:, 1:2])

    # Expert 0: Mult. (dense ReLU MLP).
    h_m = jnp.maximum(x @ w1m_ref[...] + b1m_ref[...], 0.0)
    y_m = h_m @ w2m_ref[...] + b2m_ref[...]

    # Expert 1: Shift (pow2 weights dequantized on-chip, as in matshift).
    w1 = s1_ref[...].astype(jnp.float32) * jnp.exp2(p1_ref[...].astype(jnp.float32))
    w2 = s2_ref[...].astype(jnp.float32) * jnp.exp2(p2_ref[...].astype(jnp.float32))
    h_s = jnp.maximum(x @ w1 + b1s_ref[...], 0.0)
    y_s = h_s @ w2 + b2s_ref[...]

    o_ref[...] = gval * (mult_wins * y_m + (1.0 - mult_wins) * y_s)


def _pad_tokens(a, bt):
    pad = (-a.shape[0]) % bt
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("bt",))
def moe_mlp(x, gate_w, w1m, b1m, w2m, b2m, s1, p1, b1s, s2, p2, b2s, *, bt: int = 64):
    """Dense-masked MoE MLP. Matches :func:`ref.moe_mlp_ref`.

    x: (N, d); gate_w: (d, 2); Mult expert (w1m (d,h), b1m (1,h), w2m (h,d),
    b2m (1,d)); Shift expert as int8 (sign, exp) planes + float biases.
    """
    n, d = x.shape
    h = w1m.shape[1]
    xp = _pad_tokens(x, bt)
    npad = xp.shape[0]
    grid = (npad // bt,)

    full = lambda *shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    out = pl.pallas_call(
        _moe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            full(d, 2),
            full(d, h),
            full(1, h),
            full(h, d),
            full(1, d),
            full(d, h),
            full(d, h),
            full(1, h),
            full(h, d),
            full(h, d),
            full(1, d),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, d), jnp.float32),
        interpret=True,
    )(xp, gate_w, w1m, b1m, w2m, b2m, s1, p1, b1s, s2, p2, b2s)
    return out[:n]
