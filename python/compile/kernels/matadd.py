"""MatAdd — the paper's customized add kernel (Fig. 5/8) as Pallas.

Computes ``O = X @ B`` with ``B ∈ {-1, 0, +1}`` using **sign-masked
accumulation only** — no multiply appears in the inner loop. This is the
primitive that the binarized-Q/K linear attention reduces to: a MAC against a
±1 operand is a conditional add/subtract.

The kernel materializes a (bm, bk, bn) select tensor per tile; with the
default 32³ blocks that is 128 KiB of VMEM, well within budget, and the
reduction over the K axis is a pure adder-tree — exactly the hardware story
in Table 1 (INT add = 0.1 pJ vs 3.1 pJ mult).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matadd_kernel(x_ref, b_ref, o_ref):
    """One (bm, bn) output tile, accumulated over the K grid axis.

    Inner op: o[m,n] += Σ_k select(b[k,n]) where select is +x, -x or 0 —
    accumulation only, no multiplies.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn) int8 in {-1,0,+1}
    xe = x[:, :, None]  # (bm, bk, 1)
    be = b[None, :, :]  # (1, bk, bn)
    contrib = jnp.where(be > 0, xe, jnp.where(be < 0, -xe, 0.0))
    o_ref[...] += contrib.sum(axis=1)


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matadd(x, b, *, bm: int = 32, bn: int = 32, bk: int = 32):
    """``x (M,K) f32  @  b (K,N) int8{-1,0,+1}  ->  (M,N) f32``."""
    m, k = x.shape
    k2, n = b.shape
    assert k == k2, (x.shape, b.shape)

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    bp = _pad_to(_pad_to(b, bk, 0), bn, 1)  # zero-pad: pads contribute 0

    mp, kp = xp.shape
    np_ = bp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        _matadd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, bp)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, bk: int) -> int:
    """Estimated VMEM working set per grid step (DESIGN.md §Perf)."""
    x_t = 4 * bm * bk
    b_t = bk * bn  # int8
    o_t = 4 * bm * bn
    sel = 4 * bm * bk * bn  # select tensor (interpret mode materializes it)
    return 2 * (x_t + b_t) + o_t + sel
