"""L1 — Pallas kernels for ShiftAddViT's multiplication primitives.

All kernels run with ``interpret=True`` so they lower to plain HLO that the
CPU PJRT plugin (and the Rust runtime) can execute. On a real TPU the same
BlockSpecs tile HBM→VMEM transfers for the MXU; see DESIGN.md
§Hardware-Adaptation.

Public entry points:
- :func:`matshift.matshift`       — x @ (s·2^P), power-of-two weights
- :func:`matadd.matadd`           — x @ b, b ∈ {-1,0,+1}, accumulation only
- :func:`linattn.linattn`         — binarized linear attention Q(KᵀV)
- :func:`moe_mlp.moe_mlp`         — dense-masked 2-expert MoE MLP
"""

from . import matadd, matshift, linattn, moe_mlp, ref  # noqa: F401
