"""MatShift — the paper's customized shift kernel (Fig. 4/7) as Pallas.

Computes ``O = X @ (s · 2^P)`` where the weight is stored as two INT8 planes:
sign ``s ∈ {-1,+1}`` and exponent ``P ∈ [-8, 7]``. The paper's speedup on GPU
comes from *bit reduction* (INT8 weight planes → 4× less weight traffic than
f32); the TPU mapping keeps both planes resident in VMEM and expands them to
the MXU operand on-chip, so HBM sees only the INT8 planes.

Tiling: grid (M/bm, N/bn, K/bk); X tile (bm, bk), weight tiles (bk, bn),
output tile (bm, bn) accumulated across the K grid axis (revisited output
block — the canonical Pallas matmul schedule).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matshift_kernel(x_ref, s_ref, p_ref, o_ref):
    """One (bm, bn) output tile; accumulates over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # On-chip dequantization: the only f32 multiply is the MXU matmul itself;
    # s·2^P is a sign flip + exponent load (exp2 of an integer).
    w = s_ref[...].astype(jnp.float32) * jnp.exp2(p_ref[...].astype(jnp.float32))
    o_ref[...] += x_ref[...] @ w


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matshift(x, s, p, *, bm: int = 32, bn: int = 32, bk: int = 32):
    """``x (M,K) f32  @  (s,p) (K,N) int8-planes  ->  (M,N) f32``.

    Shapes need not be multiples of the block sizes; inputs are zero-padded
    and the result sliced back (zero padding is exact for this op: padded K
    columns contribute sign·2^P·0, padded rows/cols are discarded).
    """
    m, k = x.shape
    k2, n = s.shape
    assert k == k2 and s.shape == p.shape, (x.shape, s.shape, p.shape)

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    # Padded K rows of the weight must contribute zero: pad the *input* with
    # zeros (done above) so the weight pad values are irrelevant; still pad
    # sign with +1 / exponent with 0 to keep dequantization finite.
    sp = _pad_to(_pad_to(s, bk, 0), bn, 1)
    pp = _pad_to(_pad_to(p, bk, 0), bn, 1)

    mp, kp = xp.shape
    np_ = sp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        _matshift_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, sp, pp)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, bk: int) -> int:
    """Estimated VMEM working set per grid step (for DESIGN.md §Perf).

    f32 X tile + f32 O tile + two INT8 weight planes (+ their f32 expansion,
    double-buffered inputs).
    """
    x_t = 4 * bm * bk
    o_t = 4 * bm * bn
    w_planes = 2 * bk * bn  # int8 sign + int8 exponent
    w_f32 = 4 * bk * bn
    return 2 * (x_t + w_planes) + o_t + w_f32
