"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in this package must match its oracle here to ~1e-5
(float32, interpret mode). pytest + hypothesis sweep shapes and values in
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import jax.numpy as jnp


def pow2_quantize(w: jnp.ndarray, p_min: int = -8, p_max: int = 7):
    """Reparameterize dense weights as sign * 2^P (DeepShift-PS style).

    Returns ``(s, p)`` with s ∈ {-1, +1} (int8) and p ∈ [p_min, p_max] (int8).
    Zero weights map to the smallest magnitude 2^p_min with positive sign.
    """
    a = jnp.abs(w)
    s = jnp.where(w < 0, -1, 1).astype(jnp.int8)
    safe = jnp.where(a > 0, a, 2.0 ** p_min)
    p = jnp.clip(jnp.round(jnp.log2(safe)), p_min, p_max).astype(jnp.int8)
    return s, p


def pow2_dequantize(s: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct float weights from (sign, exponent) planes."""
    return s.astype(jnp.float32) * jnp.exp2(p.astype(jnp.float32))


def binary_quantize(x: jnp.ndarray) -> jnp.ndarray:
    """Vanilla binarization [27]: msign(x) ∈ {-1, +1} (0 maps to +1)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def ksh_binarize(x: jnp.ndarray, proj: jnp.ndarray) -> jnp.ndarray:
    """Kernelized-hashing binarization (Ecoformer [34] stand-in).

    Hash = sign of a random projection in feature space: sign(x @ proj).
    ``proj`` has shape (d, b) with b hash bits; output is (..., b) in {-1,+1}.
    """
    return binary_quantize(x @ proj)


def matshift_ref(x: jnp.ndarray, s: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the MatShift kernel: x @ (s * 2^p).

    x: (M, K) float32; s, p: (K, N) int8 planes.
    """
    return x @ pow2_dequantize(s, p)


def matadd_ref(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the MatAdd kernel: x @ b with b ∈ {-1,0,+1}.

    The kernel itself computes this with sign-masked accumulation only
    (no multiplies); the oracle uses the dense product.
    """
    return x @ b.astype(x.dtype)


def linattn_ref(qb, kb, v, eps: float = 1e-6):
    """Oracle for binarized linear attention (per head).

    qb, kb: (N, d) in {-1,+1}; v: (N, d) float32.

    Attention weight = Hamming *similarity* (number of matching code bits):
    ``a_ij = (d + qb_i·kb_j) / 2 ∈ [0, d]`` — the paper's "map Q, K to binary
    codes in Hamming space". Non-negative by construction, so the normalizer
    ``Σ_j a_ij`` never crosses zero. Computed in Q(KV) order, linear in N:

        num_i = d·Σ_j v_j + qb_i @ (kbᵀ v)
        den_i = n·d       + qb_i @ (kbᵀ 1)
        out_i = num_i / den_i            (the 1/2 factors cancel)

    All MatMuls against qb/kb are sign-masked accumulations (MatAdd).
    """
    n, d = qb.shape
    kv = kb.T @ v  # (d, d)   — MatAdd: kb is ±1
    z = kb.T @ jnp.ones((n, 1), qb.dtype)  # (d, 1) — accumulation
    sv = v.sum(axis=0, keepdims=True)  # (1, d)
    num = float(d) * sv + qb @ kv  # (N, d) — MatAdd: qb is ±1
    den = float(n * d) + qb @ z  # (N, 1), ≥ 0
    return num / (den + eps)


def softmax_attn_ref(q, k, v):
    """Standard MSA oracle (per head): softmax(q kᵀ / sqrt(d)) v."""
    d = q.shape[-1]
    a = jnp.einsum("nd,md->nm", q, k) / jnp.sqrt(float(d))
    a = a - a.max(axis=-1, keepdims=True)
    a = jnp.exp(a)
    a = a / a.sum(axis=-1, keepdims=True)
    return a @ v


def moe_mlp_ref(x, gate_w, w1m, b1m, w2m, b2m, s1, p1, b1s, s2, p2, b2s):
    """Oracle for the dense-masked 2-expert MoE MLP.

    Expert 0 = Mult. MLP (dense ReLU MLP); expert 1 = Shift MLP (pow2 weights).
    Router: softmax(x @ gate_w); top-1 hard mask scaled by its gate value
    (the paper's G(x) = p_i · 1{p_i ≥ p_j}).
    """
    logits = x @ gate_w  # (N, 2)
    pgate = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    pgate = pgate / pgate.sum(axis=-1, keepdims=True)
    top = jnp.argmax(pgate, axis=-1)  # (N,)
    gval = jnp.take_along_axis(pgate, top[:, None], axis=-1)  # (N, 1)

    h_m = jnp.maximum(x @ w1m + b1m, 0.0)
    y_m = h_m @ w2m + b2m

    w1 = pow2_dequantize(s1, p1)
    w2 = pow2_dequantize(s2, p2)
    h_s = jnp.maximum(x @ w1 + b1s, 0.0)
    y_s = h_s @ w2 + b2s

    mask_m = (top == 0).astype(x.dtype)[:, None]
    return gval * (mask_m * y_m + (1.0 - mask_m) * y_s)
