"""Binarized linear attention — the fused Q(KᵀV) kernel (per head).

Attention weights are Hamming similarities between binary codes
(``a_ij = (d + qb_i·kb_j)/2 ≥ 0`` — the paper's "map queries and keys to
binary codes in Hamming space"), computed in Q(KV) order so the cost is
linear in the token count. Two Pallas phases:

1. **Aggregate**: ``KV = KbᵀV``, ``Z = Kbᵀ1`` and ``SV = Σv`` accumulated
   over token blocks. With ``Kb ∈ {-1,+1}`` the first two are MatAdd-style
   sign-masked accumulations.
2. **Apply**: ``O = (d·SV + Qb@KV) / (n·d + Qb@Z)`` per token block; ``Qb``
   binary again makes the numerator an accumulation.

The d×d ``KV`` stays resident in VMEM across token blocks — the TPU
translation of the paper's CUDA schedule (KV in shared memory).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _aggregate_kernel(kb_ref, v_ref, kv_ref, z_ref, sv_ref):
    """Accumulate KV (d,d), Z (d,1), SV (1,d) over token-block grid axis 0."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        kv_ref[...] = jnp.zeros_like(kv_ref)
        z_ref[...] = jnp.zeros_like(z_ref)
        sv_ref[...] = jnp.zeros_like(sv_ref)

    kb = kb_ref[...]  # (bt, d) in {-1,+1}; zero-padded rows contribute 0
    v = v_ref[...]  # (bt, d)
    # Sign-masked accumulation: kbᵀ v with ±1 entries (pad rows: kb=0, v=0 ⇒
    # the -v branch adds -0).
    kbe = kb[:, :, None]  # (bt, d, 1)
    ve = v[:, None, :]  # (bt, 1, d)
    kv_ref[...] += jnp.where(kbe > 0, ve, -ve).sum(axis=0)
    z_ref[...] += kb.sum(axis=0)[:, None]
    sv_ref[...] += v.sum(axis=0)[None, :]


def _apply_kernel(qb_ref, kv_ref, z_ref, sv_ref, nd_ref, o_ref):
    """O = (d·SV + Qb@KV) / (n·d + Qb@Z + eps) for one token block."""
    qb = qb_ref[...]  # (bt, d)
    kv = kv_ref[...]  # (d, d)
    z = z_ref[...]  # (d, 1)
    sv = sv_ref[...]  # (1, d)
    qbe = qb[:, :, None]  # (bt, d, 1)
    num = jnp.where(qbe > 0, kv[None, :, :], -kv[None, :, :]).sum(axis=1)
    den = jnp.where(qb > 0, z[:, 0][None, :], -z[:, 0][None, :]).sum(
        axis=1, keepdims=True
    )
    n = nd_ref[0]  # token count
    d = nd_ref[1]  # head dim
    o_ref[...] = (d * sv + num) / (n * d + den + 1e-6)


def _pad_tokens(a, bt):
    pad = (-a.shape[0]) % bt
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("bt",))
def linattn(qb, kb, v, *, bt: int = 64):
    """Binarized linear attention for one head.

    qb, kb: (N, d) float32 with values in {-1,+1}; v: (N, d) float32.
    Matches :func:`ref.linattn_ref`. N need not be a multiple of ``bt``:
    zero-padded tokens contribute nothing to KV/Z/SV (see kernel comments)
    and their outputs are sliced away.
    """
    n, d = qb.shape
    qp = _pad_tokens(qb, bt)
    kp = _pad_tokens(kb, bt)
    vp = _pad_tokens(v, bt)
    npad = qp.shape[0]
    grid = (npad // bt,)

    kv, z, sv = pl.pallas_call(
        _aggregate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((d, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
        ],
        interpret=True,
    )(kp, vp)

    ndvec = jnp.asarray([float(n), float(d)], jnp.float32)
    out = pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, d), jnp.float32),
        interpret=True,
    )(qp, kv, z, sv, ndvec)
    return out[:n]
