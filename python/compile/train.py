"""Build-time training: the paper's two-stage reparameterization finetune.

Stages (paper §5.1, Appendix E), scaled to the synthetic task:

- **stage 0** — train the MSA baseline from scratch (substitute for the
  public pre-trained ViT checkpoints),
- **stage 1** — convert MSA → linear attention + reparameterize attention
  MatMuls with Add layers (binarized Q/K), finetune,
- **stage 2** — reparameterize MLPs/linears with Shift or MoE layers,
  finetune with L_CLS + λ(L_IMP + L_LOAD), λ = 0.01.

Expert latency coefficients α_i for the LL-loss come from the measured
Mult/Shift expert costs (Eyeriss model ratios; overridable via --alphas).

Outputs: ``python/trained/<model>_<variant>.npz`` checkpoints and
``python/trained/results.json`` (accuracy per variant — consumed by the Rust
bench harness for the accuracy columns of Tables 2/3/4/6 and EXPERIMENTS.md).

Usage:
    python -m compile.train --preset main           # stage0..2 on pvtv2_b0
    python -m compile.train --preset sensitivity    # Table 2
    python -m compile.train --preset llloss         # Table 7 (w/ vs w/o)
    python -m compile.train --preset models         # stage ladder, all sizes
    python -m compile.train --preset nvs            # Table 5 scenes
    python -m compile.train --preset lra            # Table 11 tasks
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M
from . import model_lra as LRA
from . import model_nvs as NVS
from .params_io import TRAINED_DIR, load_params, save_params, trained_path

RESULTS = os.path.join(TRAINED_DIR, "results.json")


def record(key: str, value: Any):
    os.makedirs(TRAINED_DIR, exist_ok=True)
    blob = {}
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            blob = json.load(f)
    blob[key] = value
    with open(RESULTS, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)


# ----------------------------------------------------------------- optimizer


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8, clip=1.0):
    """Adam with global-norm gradient clipping and a non-finite-update guard
    (binarized-attention STE gradients occasionally spike; a single bad step
    would otherwise poison the checkpoint and cascade NaN into every later
    reparameterization stage)."""
    # global-norm clip
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) + 1e-12
    )
    scale = jnp.minimum(1.0, clip / gnorm)
    grads = jax.tree.map(lambda g: g * scale, grads)
    # replace any non-finite grads with zero (skip those coordinates)
    grads = jax.tree.map(lambda g: jnp.where(jnp.isfinite(g), g, 0.0), grads)
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
    new = jax.tree.map(lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# ------------------------------------------------------------ classification


def eval_acc(params, cfg, var, n=256, seed0=10_000_000, bs=64):
    correct = 0
    for s in range(0, n, bs):
        xs, ys = D.gen_batch(seed0 + s, min(bs, n - s))
        logits, _ = M.forward(params, jnp.asarray(xs), cfg, var, use_pallas=False)
        correct += int((jnp.argmax(logits, -1) == jnp.asarray(ys)).sum())
    return correct / n


def train_classifier(
    mname: str,
    vname: str,
    steps: int,
    *,
    init_from: str | None = None,
    lr: float = 2e-3,
    bs: int = 32,
    alphas=(0.8, 0.2),
    lam: float = 0.01,
    log_every: int = 50,
    tag: str | None = None,
):
    """Train/finetune one (model, variant); returns final accuracy."""
    cfg = M.MODELS[mname]
    var = M.VARIANTS[vname]
    tag = tag or f"{mname}_{vname}"
    if init_from and os.path.exists(trained_path(mname, init_from)):
        params = load_params(mname, init_from, cfg)
        lr = lr * 0.5  # finetune stages use a reduced lr (paper Appendix E)
        print(f"[{tag}] init from {mname}_{init_from}")
    else:
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        print(f"[{tag}] init from scratch")
    a = jnp.asarray(alphas, jnp.float32)

    @jax.jit
    def step(params, opt, x, y, lr_t):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: M.classification_loss(p, x, y, cfg, var, a, lam), has_aux=True
        )(params)
        params, opt = adam_update(params, grads, opt, lr_t)
        return params, opt, loss

    opt = adam_init(params)
    losses = []
    t0 = time.time()
    for it in range(steps):
        xs, ys = D.gen_batch(1 + it * bs, bs)
        # cosine-decayed lr (paper uses a cosine scheduler, Appendix E)
        lr_t = lr * 0.5 * (1.0 + np.cos(np.pi * it / max(steps, 1)))
        params, opt, loss = step(params, opt, jnp.asarray(xs), jnp.asarray(ys), lr_t)
        losses.append(float(loss))
        if (it + 1) % log_every == 0 or it == 0:
            print(f"[{tag}] step {it+1}/{steps} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    acc = eval_acc(params, cfg, var)
    print(f"[{tag}] eval acc {acc*100:.2f}%")
    save_params(params, trained_path(mname, vname) if tag == f"{mname}_{vname}" else os.path.join(TRAINED_DIR, f"{tag}.npz"))
    record(tag, {"acc": acc, "steps": steps, "loss_curve": losses[:: max(1, steps // 50)], "final_loss": losses[-1]})
    return acc


def preset_main(args):
    """Stage ladder on pvtv2_b0: the paper's two-stage pipeline."""
    s = args.steps
    train_classifier("pvtv2_b0", "msa", 2 * s)  # stage 0 "pretrain"
    for v in ("linear", "add_quant", "add_ksh"):  # stage 1
        train_classifier("pvtv2_b0", v, s, init_from="msa")
    for v in ("add_quant_shift_both", "add_quant_moe_both", "add_ksh_moe_both", "add_ksh_shiftattn", "add_ksh_shiftattn_moe"):
        train_classifier("pvtv2_b0", v, s, init_from="add_quant")  # stage 2


def preset_models(args):
    """Stage ladder for the other sizes (Table 3)."""
    s = args.steps
    for mname in ("pvtv1_t", "pvtv2_b1", "pvtv2_b2", "deit_t"):
        train_classifier(mname, "msa", 2 * s)
        train_classifier(mname, "add_quant", s, init_from="msa")
        train_classifier(mname, "add_quant_moe_both", s, init_from="add_quant")


def preset_sensitivity(args):
    """Table 2: apply each component separately, short finetune."""
    s = max(args.steps // 2, 50)
    for mname in ("pvtv2_b0", "pvtv1_t"):
        if not os.path.exists(trained_path(mname, "msa")):
            train_classifier(mname, "msa", 2 * args.steps)
        for v in ("linear", "add_quant", "shift_mlp", "moe_mlp"):
            train_classifier(mname, v, s, init_from="msa", tag=f"sens_{mname}_{v}")


def preset_llloss(args):
    """Table 7: MoE finetune with vs without the LL-loss."""
    s = args.steps
    for mname in ("pvtv2_b0", "pvtv1_t"):
        if not os.path.exists(trained_path(mname, "add_quant")):
            train_classifier(mname, "msa", 2 * s)
            train_classifier(mname, "add_quant", s, init_from="msa")
        train_classifier(mname, "add_quant_moe_both", s, init_from="add_quant", tag=f"llloss_{mname}_with")
        train_classifier(mname, "add_quant_moe_both", s, init_from="add_quant", lam=0.0, tag=f"llloss_{mname}_without")


# --------------------------------------------------------------------- NVS


def train_nvs(scene: str, vname: str, steps: int, lr=3e-3, rays=512):
    cfg = NVS.NVS_CFG
    var = NVS.NVS_VARIANTS[vname]
    tag = f"nvs_{scene}_{vname}"
    base = os.path.join(TRAINED_DIR, f"nvs_{scene}_gnt.npz")
    if vname != "gnt" and os.path.exists(base):
        from .params_io import load_params_nvs

        params = load_params_nvs(scene, "gnt")
    else:
        params = NVS.init_nvs_params(jax.random.PRNGKey(1))
    scene_def = NVS.SCENES[scene]

    @jax.jit
    def step(params, opt, o, d, target):
        def loss_fn(p):
            rgb = NVS.nvs_forward(p, o, d, var, cfg)
            return ((rgb - target) ** 2).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for it in range(steps):
        # Random rays from random poses (the paper samples 2048/iter; we 512).
        angle = float(rng.uniform(-0.3, 0.3))
        o_all, d_all = NVS.camera_rays(32, angle)
        idx = rng.integers(0, o_all.shape[0], rays)
        o, d = o_all[idx], d_all[idx]
        target = NVS.ray_trace(scene_def, o, d)
        params, opt, loss = step(params, opt, jnp.asarray(o), jnp.asarray(d), jnp.asarray(target))
        if (it + 1) % 50 == 0 or it == 0:
            print(f"[{tag}] step {it+1}/{steps} mse {float(loss):.5f} ({time.time()-t0:.0f}s)")
    # Eval: full render at held-out pose.
    o_all, d_all = NVS.camera_rays(32, 0.15)
    gt = NVS.ray_trace(scene_def, o_all, d_all)
    pred = np.asarray(NVS.nvs_forward(params, jnp.asarray(o_all), jnp.asarray(d_all), var, cfg))
    mse = float(((pred - gt) ** 2).mean())
    psnr = -10.0 * np.log10(mse + 1e-12)
    print(f"[{tag}] PSNR {psnr:.2f}")
    save_params(params, os.path.join(TRAINED_DIR, f"{tag}.npz"))
    record(tag, {"psnr": psnr, "mse": mse, "steps": steps})
    return psnr


def preset_nvs(args):
    scenes = args.scenes.split(",")
    for scene in scenes:
        train_nvs(scene, "gnt", args.steps)
        for v in ("add", "add_shift_both", "add_shiftattn_moe", "shift_both"):
            train_nvs(scene, v, args.steps // 2)


# --------------------------------------------------------------------- LRA


def train_lra(task: str, attn: str, steps: int, lr=3e-3, bs=32):
    cfg = LRA.LRA_CFG
    tag = f"lra_{task}_{attn}"
    params = LRA.init_lra_params(jax.random.PRNGKey(2))

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            logits = LRA.lra_forward(p, x, attn, cfg)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    opt = adam_init(params)
    t0 = time.time()
    for it in range(steps):
        xs, ys = LRA.gen_task(task, 1 + it, bs)
        params, opt, loss = step(params, opt, jnp.asarray(xs), jnp.asarray(ys))
        if (it + 1) % 50 == 0 or it == 0:
            print(f"[{tag}] step {it+1}/{steps} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    # Eval.
    correct = total = 0
    for s in range(8):
        xs, ys = LRA.gen_task(task, 900_000 + s, 32)
        logits = LRA.lra_forward(params, jnp.asarray(xs), attn, cfg)
        correct += int((jnp.argmax(logits, -1) == jnp.asarray(ys)).sum())
        total += 32
    acc = correct / total
    print(f"[{tag}] acc {acc*100:.2f}%")
    save_params(params, os.path.join(TRAINED_DIR, f"{tag}.npz"))
    record(tag, {"acc": acc, "steps": steps})
    return acc


def preset_lra(args):
    for task in args.tasks.split(","):
        for attn in LRA.LRA_ATTNS:
            train_lra(task, attn, args.steps)


PRESETS = {
    "main": preset_main,
    "models": preset_models,
    "sensitivity": preset_sensitivity,
    "llloss": preset_llloss,
    "nvs": preset_nvs,
    "lra": preset_lra,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", required=True, choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scenes", default="orchids,flower")
    ap.add_argument("--tasks", default="text,listops,retrieval,image")
    args = ap.parse_args()
    PRESETS[args.preset](args)


if __name__ == "__main__":
    main()
