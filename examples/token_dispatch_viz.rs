//! Fig. 6/9 — visualize the MoE router's token dispatch: object tokens
//! should flow to the powerful Mult expert, background tokens to the cheap
//! Shift expert. Prints ASCII grids and writes overlay PPMs.
//!
//! ```sh
//! make artifacts && cargo run --release --example token_dispatch_viz
//! ```

use anyhow::Result;
use shiftaddvit::coordinator::config::DispatchMode;
use shiftaddvit::coordinator::metrics::Metrics;
use shiftaddvit::coordinator::scheduler::MoePipeline;
use shiftaddvit::data::synth_images;
use shiftaddvit::runtime::artifact::Manifest;
use shiftaddvit::util::image::{ascii_grid, overlay_dispatch, write_ppm};

fn main() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let serve = manifest.serve.clone().expect("serving topology");
    let pipeline = MoePipeline::new(&manifest, DispatchMode::Real)?;
    pipeline.warmup()?;
    let grid = (serve.tokens as f64).sqrt() as usize;
    let out_dir = std::path::Path::new("out/dispatch");
    std::fs::create_dir_all(out_dir)?;

    let mut metrics = Metrics::default();
    let mut iou_sum = 0.0;
    let n = 6u32;
    for i in 0..n {
        let s = synth_images::gen_image(9_100_000 + i);
        let out = pipeline.run_batch(&s.pixels, 1, &mut metrics)?;
        let mask = &out.dispatch_mask_blk0[0];
        let gt = synth_images::object_mask(&s, serve.patch);
        let inter = mask.iter().zip(&gt).filter(|(a, b)| **a && **b).count() as f64;
        let union = mask.iter().zip(&gt).filter(|(a, b)| **a || **b).count().max(1) as f64;
        iou_sum += inter / union;

        println!(
            "\nimage {i}: label {} — router dispatch | ground-truth object tokens (IoU {:.2})",
            synth_images::SHAPE_NAMES[s.label],
            inter / union
        );
        let left = ascii_grid(mask, grid);
        let right = ascii_grid(&gt, grid);
        for (l, r) in left.lines().zip(right.lines()) {
            println!("  {l}    {r}");
        }
        let overlay = overlay_dispatch(&s.pixels, 32, 32, mask, grid);
        write_ppm(&out_dir.join(format!("dispatch_{i}.ppm")), &overlay, 32, 32)?;
    }
    println!(
        "\nmean IoU(router Mult-tokens, object tokens) = {:.3}  (≫ chance for a trained router)",
        iou_sum / n as f64
    );
    println!(
        "expert load split [Mult, Shift] = {:?}",
        metrics.load_split()
    );
    println!("overlay PPMs written to {out_dir:?}");
    Ok(())
}
