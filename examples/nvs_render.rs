//! Fig. 10 — qualitative NVS renders: ray-trace ground truth vs the GNT-style
//! ray transformer and its ShiftAddViT reparameterizations; writes PPMs.
//!
//! ```sh
//! make artifacts && cargo run --release --example nvs_render
//! ```

use anyhow::Result;
use shiftaddvit::harness::nvs::NVS_LADDER;
use shiftaddvit::nvs::render::eval_scene;
use shiftaddvit::nvs::scenes::Scene;
use shiftaddvit::runtime::engine::Engine;
use shiftaddvit::util::image::write_ppm;

fn main() -> Result<()> {
    let engine = Engine::from_default_dir()?;
    let out = std::path::Path::new("out/nvs");
    std::fs::create_dir_all(out)?;
    let img = 32;
    for scene_name in ["orchids", "flower"] {
        let scene = Scene::from_manifest(&engine.manifest().root, scene_name)?;
        let gt = scene.render_gt(img, 0.15);
        write_ppm(&out.join(format!("{scene_name}_gt.ppm")), &gt, img, img)?;
        println!("scene '{scene_name}' (ground truth written)");
        for (artifact, label, _) in NVS_LADDER {
            match eval_scene(&engine, &scene, artifact, img, 0.15) {
                Ok(e) => {
                    write_ppm(
                        &out.join(format!("{scene_name}_{artifact}.ppm")),
                        &e.pred,
                        img,
                        img,
                    )?;
                    println!(
                        "  {label:40} PSNR {:6.2}  SSIM {:.3}  LPIPS* {:.3}",
                        e.psnr, e.ssim, e.lpips
                    );
                }
                Err(err) => println!("  {label:40} unavailable: {err}"),
            }
        }
    }
    println!("\nPPM files in {out:?} — view with any image tool.");
    Ok(())
}
