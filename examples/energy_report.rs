//! Energy/area walkthrough: Table 1 op costs → MAC styles → Eyeriss energy
//! for every model/variant → area-constrained latency (Table 13 mechanics).
//! Pure analytics — runs without artifacts.
//!
//! ```sh
//! cargo run --release --example energy_report
//! ```

use shiftaddvit::energy::area::AreaModel;
use shiftaddvit::energy::eyeriss::{energy, Hierarchy};
use shiftaddvit::energy::ops::MacStyle;
use shiftaddvit::harness::figures;
use shiftaddvit::model::config::classifier;
use shiftaddvit::model::ops::{count, Variant};

fn main() {
    figures::table1();

    let h = Hierarchy::default();
    let a = AreaModel::default();
    println!("\nPE counts under the 168-FP32-PE area budget:");
    for s in [
        MacStyle::MultFp32,
        MacStyle::MultInt8,
        MacStyle::ShiftInt32,
        MacStyle::AddInt32,
    ] {
        println!("  {s:?}: {} PEs", a.pes(s) as usize);
    }

    for model in ["pvtv2_b0", "pvtv1_t", "pvtv2_b1", "pvtv2_b2", "deit_t"] {
        let spec = classifier(model);
        println!("\n=== {} ===", spec.name);
        println!(
            "{:20} {:>10} {:>12} {:>12} {:>12} {:>14}",
            "variant", "GMACs", "compute mJ", "DRAM mJ", "total mJ", "area-lat ms"
        );
        for (label, var) in [
            ("MSA", Variant::MSA),
            ("Linear", Variant::LINEAR),
            ("Linear+Add", Variant::ADD),
            ("+ShiftAttn", Variant::ADD_SHIFT_ATTN),
            ("+ShiftBoth", Variant::ADD_SHIFT_BOTH),
            ("+MoE(50/50)", Variant::SHIFTADD_MOE),
        ] {
            let ops = count(&spec, var);
            let r = energy(&ops, &h);
            println!(
                "{:20} {:>10.2} {:>12.2} {:>12.2} {:>12.2} {:>14.2}",
                label,
                ops.total_macs() / 1e9,
                r.compute_mj,
                r.dram_mj,
                r.total_mj(),
                a.latency_ms(&ops)
            );
        }
    }
    println!("\nFig. 3 companion:");
    figures::fig3_energy_breakdown();
}
