//! Quickstart: load one AOT-compiled ShiftAddViT artifact, classify a few
//! synthetic images, and print what the stack just did.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use shiftaddvit::data::synth_images;
use shiftaddvit::runtime::engine::Engine;
use shiftaddvit::runtime::tensor::Tensor;

fn main() -> Result<()> {
    // The engine owns a PJRT CPU client and a compile cache over the
    // HLO-text artifacts produced (once) by `python/compile/aot.py`.
    let engine = Engine::from_default_dir()?;
    println!(
        "loaded manifest with {} artifacts from {:?}",
        engine.manifest().models.len(),
        engine.manifest().dir
    );

    // Pick the fully reparameterized ShiftAddViT: linear attention with
    // binarized Q/K (adds), MoE MLPs (Mult + Shift experts).
    let name = "cls_pvtv2_b0_add_quant_moe_both_bs1";
    let compiled = engine.load(name)?;
    println!("compiled '{name}' in {:.1} ms", compiled.compile_ms);

    let mut correct = 0;
    let n = 16;
    for seed in 0..n {
        let sample = synth_images::gen_image(123_000 + seed);
        let logits = engine.run(
            &compiled,
            &[Tensor::f32(vec![1, 32, 32, 3], sample.pixels.clone())],
        )?;
        let pred = logits[0].argmax_last()?[0];
        if pred == sample.label {
            correct += 1;
        }
        if seed < 4 {
            println!(
                "  image {seed}: true={:8} pred={:8}",
                synth_images::SHAPE_NAMES[sample.label],
                synth_images::SHAPE_NAMES[pred]
            );
        }
    }
    println!(
        "accuracy on {n} held-out synthetic images: {:.0}% \
         (reflects trained checkpoints if `make train` ran before `make artifacts`)",
        100.0 * correct as f64 / n as f64
    );
    Ok(())
}
