//! Quickstart: classify a few synthetic images and print what the stack
//! just did. Defaults to the native pure-Rust engine, so it runs out of the
//! box with zero setup; pass `--backend xla` to use an AOT-compiled
//! artifact instead (requires `make artifacts`).
//!
//! ```sh
//! cargo run --release --example quickstart                # native engine
//! make artifacts && cargo run --release --example quickstart -- --backend xla
//! ```

use anyhow::Result;
use shiftaddvit::coordinator::config::BackendKind;
use shiftaddvit::data::synth_images;
use shiftaddvit::infer::model::NativeModel;
use shiftaddvit::infer::session::{StreamAttn, StreamModel};
use shiftaddvit::model::ops::{Lin, Variant};
use shiftaddvit::runtime::engine::Engine;
use shiftaddvit::runtime::tensor::Tensor;
use shiftaddvit::util::cli::Args;
use shiftaddvit::util::rng::XorShift64;

fn main() -> Result<()> {
    let args = Args::parse();
    match BackendKind::parse(&args.get_or("backend", "native"))? {
        BackendKind::Native => {
            quickstart_native()?;
            quickstart_sessions()
        }
        BackendKind::Xla => quickstart_xla(),
    }
}

/// The session-based streaming API in a nutshell: tokens stream through the
/// O(d·bits) linear-attention state chunk by chunk — no prefix re-runs —
/// and the chunked result is bit-exact against one-shot recompute.
fn quickstart_sessions() -> Result<()> {
    let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Shift);
    let d = model.spec.dim;
    println!(
        "\nstreaming sessions: {} layers, dim {}, {} f32s of state per session \
         (constant — no KV cache)",
        model.spec.depth,
        d,
        model.spec.state_floats()
    );
    let tokens = XorShift64::new(7).normals(12 * d);
    let mut session = model.begin();
    for chunk in tokens.chunks(4 * d) {
        model.extend(&mut session, chunk); // stream 4 tokens at a time
    }
    let streamed = model.finish(&session);
    let oneshot = model.forward_full(&tokens);
    assert_eq!(streamed, oneshot, "chunked streaming must be bit-exact");
    println!(
        "streamed 12 tokens in 3 chunks; logits[0..3] = {:?} (bit-exact vs one-shot)",
        &streamed[..3]
    );
    Ok(())
}

fn quickstart_native() -> Result<()> {
    // The fully reparameterized ShiftAddViT: KSH-binarized LinearAdd
    // attention (MatAdd kernels), shift attention linears (MatShift), and
    // the Mult/Shift MoE MLP — all on planner-chosen registry backends.
    let model = NativeModel::tiny(Variant::SHIFTADD_MOE);
    println!(
        "built native '{}' ({} blocks); planner decided {} kernel shapes:",
        model.cfg.spec.name,
        model.num_blocks(),
        model.planner.choices().len()
    );
    for c in model.planner.choices() {
        println!(
            "  {:10} {:>4}x{:<4}x{:<4} -> {}",
            c.primitive.name(),
            c.shape.m,
            c.shape.k,
            c.shape.n,
            c.backend
        );
    }

    let mut correct = 0;
    let n = 16;
    for seed in 0..n {
        let sample = synth_images::gen_image(123_000 + seed);
        let (logits, _) = model.forward(&sample.pixels, 1);
        let pred = Tensor::f32(vec![1, model.cfg.num_classes], logits).argmax_last()?[0];
        if pred == sample.label {
            correct += 1;
        }
        if seed < 4 {
            println!(
                "  image {seed}: true={:8} pred={:8}",
                synth_images::SHAPE_NAMES[sample.label],
                synth_images::SHAPE_NAMES[pred]
            );
        }
    }
    println!(
        "accuracy on {n} synthetic images: {:.0}% \
         (seed-initialized weights — chance is 12.5%; the XLA path carries \
         trained checkpoints)",
        100.0 * correct as f64 / n as f64
    );
    Ok(())
}

fn quickstart_xla() -> Result<()> {
    // The engine owns a PJRT CPU client and a compile cache over the
    // HLO-text artifacts produced (once) by `python/compile/aot.py`.
    let engine = Engine::from_default_dir()?;
    println!(
        "loaded manifest with {} artifacts from {:?}",
        engine.manifest().models.len(),
        engine.manifest().dir
    );

    let name = "cls_pvtv2_b0_add_quant_moe_both_bs1";
    let compiled = engine.load(name)?;
    println!("compiled '{name}' in {:.1} ms", compiled.compile_ms);

    let mut correct = 0;
    let n = 16;
    for seed in 0..n {
        let sample = synth_images::gen_image(123_000 + seed);
        let logits = engine.run(
            &compiled,
            &[Tensor::f32(vec![1, 32, 32, 3], sample.pixels.clone())],
        )?;
        let pred = logits[0].argmax_last()?[0];
        if pred == sample.label {
            correct += 1;
        }
        if seed < 4 {
            println!(
                "  image {seed}: true={:8} pred={:8}",
                synth_images::SHAPE_NAMES[sample.label],
                synth_images::SHAPE_NAMES[pred]
            );
        }
    }
    println!(
        "accuracy on {n} held-out synthetic images: {:.0}% \
         (reflects trained checkpoints if `make train` ran before `make artifacts`)",
        100.0 * correct as f64 / n as f64
    );
    Ok(())
}
