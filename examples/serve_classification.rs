//! END-TO-END DRIVER (DESIGN.md §5): the full serving system on a real
//! workload — synthetic clients issue image requests; the coordinator
//! batches them, runs the pipeline-decomposed ShiftAddViT with REAL sparse
//! MoE dispatch (Mult/Shift experts on parallel engine workers), and reports
//! latency, throughput, accuracy, expert load split, and LL-loss
//! diagnostics. Compares all three dispatch modes.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_classification
//! ```

use anyhow::Result;
use shiftaddvit::coordinator::config::{DispatchMode, ServerConfig};
use shiftaddvit::coordinator::server::serve;
use shiftaddvit::runtime::artifact::Manifest;
use shiftaddvit::util::image::ascii_grid;

fn main() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let serve_cfg = manifest.serve.as_ref().expect("serving topology");
    println!(
        "serving {} ({} blocks, {} tokens, dim {})\n",
        serve_cfg.model, serve_cfg.depth, serve_cfg.tokens, serve_cfg.dim
    );

    for (label, mode) in [
        ("REAL dispatch (paper '†': wall-clock parallel experts)", DispatchMode::Real),
        ("MODULARIZED (paper '*': ideal parallelism accounting)", DispatchMode::Modularized),
        ("DENSE (PVT+MoE baseline: every token through both experts)", DispatchMode::Dense),
    ] {
        println!("==================== {label} ====================");
        let cfg = ServerConfig {
            requests: 64,
            max_batch: 8,
            batch_deadline_ms: 2.0,
            dispatch: mode,
            arrival_ms: 0.0,
        };
        let report = serve(&manifest, &cfg)?;
        report.print();
        if mode == DispatchMode::Real {
            if let Some(mask) = report.sample_masks.first() {
                let grid = (serve_cfg.tokens as f64).sqrt() as usize;
                println!("\nsample router dispatch (█=Mult expert, ·=Shift expert):");
                println!("{}", ascii_grid(mask, grid));
            }
        }
        println!();
    }
    Ok(())
}
