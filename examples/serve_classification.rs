//! END-TO-END DRIVER (DESIGN.md §5): the full serving system on a real
//! workload — synthetic clients issue image requests; the coordinator
//! batches them, runs the decomposed ShiftAddViT with REAL sparse MoE
//! dispatch, and reports latency, throughput, accuracy, expert load split,
//! and LL-loss diagnostics.
//!
//! Defaults to the native pure-Rust engine, so it runs with zero setup:
//!
//! ```sh
//! cargo run --release --example serve_classification                  # native
//! make artifacts && \
//! cargo run --release --example serve_classification -- --backend xla # artifacts
//! ```
//!
//! The xla path compares all three dispatch modes (paper '†'/'*'/dense).

use anyhow::Result;
use shiftaddvit::coordinator::backend::{create_backend, InferenceBackend};
use shiftaddvit::coordinator::config::{BackendKind, DispatchMode, ServerConfig};
use shiftaddvit::coordinator::server::{serve, serve_backend};
use shiftaddvit::runtime::artifact::Manifest;
use shiftaddvit::util::cli::Args;
use shiftaddvit::util::image::ascii_grid;

fn main() -> Result<()> {
    let args = Args::parse();
    match BackendKind::parse(&args.get_or("backend", "native"))? {
        BackendKind::Native => serve_native(args.get("planner-table")),
        BackendKind::Xla => serve_xla(),
    }
}

fn serve_native(planner_table: Option<&str>) -> Result<()> {
    // All backend construction goes through `create_backend`, so the
    // `--backend` and `--planner-table` flags apply uniformly here, in the
    // CLI, and in the benches.
    let cfg = ServerConfig {
        requests: 64,
        max_batch: 8,
        batch_deadline_ms: 2.0,
        arrival_ms: 0.0,
        planner_table: planner_table.map(|s| s.to_string()),
        ..ServerConfig::default()
    };
    let backend = create_backend(&cfg)?;
    println!(
        "serving {} ({} tokens/img, {} classes) — no artifacts needed\n",
        backend.name(),
        backend.tokens(),
        backend.num_classes()
    );
    let report = serve_backend(backend.as_ref(), &cfg)?;
    report.print();
    if let Some(mask) = report.sample_masks.first() {
        let grid = (backend.tokens() as f64).sqrt() as usize;
        println!("\nsample router dispatch (█=Mult expert, ·=Shift expert):");
        println!("{}", ascii_grid(mask, grid));
    }
    Ok(())
}

fn serve_xla() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let serve_cfg = manifest.serve.as_ref().expect("serving topology");
    println!(
        "serving {} ({} blocks, {} tokens, dim {})\n",
        serve_cfg.model, serve_cfg.depth, serve_cfg.tokens, serve_cfg.dim
    );

    for (label, mode) in [
        ("REAL dispatch (paper '†': wall-clock parallel experts)", DispatchMode::Real),
        ("MODULARIZED (paper '*': ideal parallelism accounting)", DispatchMode::Modularized),
        ("DENSE (PVT+MoE baseline: every token through both experts)", DispatchMode::Dense),
    ] {
        println!("==================== {label} ====================");
        let cfg = ServerConfig {
            requests: 64,
            max_batch: 8,
            batch_deadline_ms: 2.0,
            dispatch: mode,
            arrival_ms: 0.0,
            ..ServerConfig::default()
        };
        let report = serve(&manifest, &cfg)?;
        report.print();
        if mode == DispatchMode::Real {
            if let Some(mask) = report.sample_masks.first() {
                let grid = (serve_cfg.tokens as f64).sqrt() as usize;
                println!("\nsample router dispatch (█=Mult expert, ·=Shift expert):");
                println!("{}", ascii_grid(mask, grid));
            }
        }
        println!();
    }
    Ok(())
}
