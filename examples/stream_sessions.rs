//! STREAMING SESSIONS DRIVER: the session-based inference API end to end —
//! open N sessions, stream token chunks through the KV-free
//! linear-attention state, and read logits — then the same workload through
//! the continuous-batching [`SessionEngine`], which packs every live
//! session's next chunk into ONE fused MatMul/MatShift dispatch per linear
//! per layer per step. Runs with zero setup (no artifacts):
//!
//! ```sh
//! cargo run --release --example stream_sessions
//! cargo run --release --example stream_sessions -- --sessions 8 --tokens 96 --chunk 8
//! ```

use anyhow::Result;
use shiftaddvit::coordinator::metrics::Metrics;
use shiftaddvit::coordinator::server::stream_workload_lens;
use shiftaddvit::coordinator::sessions::SessionEngine;
use shiftaddvit::infer::session::{SessionSpec, StreamAttn, StreamModel};
use shiftaddvit::kernels::planner::Planner;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::model::ops::Lin;
use shiftaddvit::util::cli::Args;
use shiftaddvit::util::rng::XorShift64;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::parse();
    let sessions = args.usize_or("sessions", 6)?;
    let mean_tokens = args.usize_or("tokens", 48)?;
    let chunk = args.usize_or("chunk", 8)?;
    let max_live = args.usize_or("max-live", 4)?;

    // The paper's deployed mixture: KSH-binarized Hamming attention (as
    // streaming scalar state updates) + shift-reparameterized linears
    // (fused MatShift dispatches). One shared planner so every engine
    // below executes identical kernel backends.
    let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
    let spec = SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift);
    let model = StreamModel::new(spec.clone(), Arc::clone(&planner));
    let d = model.spec.dim;
    println!(
        "stream model: {} layers, dim {}, {} heads — {} f32s of session state \
         (constant in sequence length; a KV cache would grow per token)\n",
        model.spec.depth, d, model.spec.heads, model.spec.state_floats()
    );

    // ---- 1. the session API, one request at a time -----------------------
    // Sessions of different lengths; each streams in `chunk`-token pieces.
    let lens = stream_workload_lens(sessions, mean_tokens);
    let seqs: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| XorShift64::new(0xE0_0 + i as u64).normals(n * d))
        .collect();
    println!("opening {sessions} sessions (lengths {lens:?}), chunk {chunk}:");
    let mut solo_logits = Vec::new();
    for (i, seq) in seqs.iter().enumerate() {
        let mut s = model.begin();
        for c in seq.chunks(chunk * d) {
            model.extend(&mut s, c);
        }
        let logits = model.finish(&s);
        println!(
            "  session {i}: {} tokens in {} chunks -> logits[0] {:+.4}",
            s.tokens_seen,
            seq.chunks(chunk * d).count(),
            logits[0]
        );
        solo_logits.push(logits);
    }

    // ---- 2. the same workload, continuously batched ----------------------
    let mut engine = SessionEngine::new(model, chunk, max_live);
    let tickets: Vec<_> = seqs.iter().map(|s| engine.submit(s.clone())).collect();
    let mut metrics = Metrics::default();
    let steps = engine.run_to_completion(&mut metrics);
    println!(
        "\ncontinuous batching: {} sessions drained in {} fused steps (≤{} live at once)",
        sessions, steps, max_live
    );
    for (i, t) in tickets.iter().enumerate() {
        let out = engine.poll(t).expect("completed");
        assert_eq!(
            out.logits, solo_logits[i],
            "fused stepping must be bit-exact vs per-session streaming"
        );
    }
    println!("bit-exactness: fused multi-session steps == per-session streaming ✓");
    if let Some(o) = metrics.occupancy_summary() {
        println!("occupancy: mean {:.0}% of {} live slots", 100.0 * o.mean, max_live);
    }
    if let Some(s) = metrics.step_tokens_summary() {
        println!("tokens per fused step: mean {:.1}", s.mean);
    }

    // ---- 3. phase-disaggregated: decode dispatches alone, prompts catch
    // ----    up in a budgeted prefill dispatch (the serve-loop default)
    let budget = chunk * max_live;
    let model2 = StreamModel::new(spec, planner);
    let mut engine = SessionEngine::disaggregated(model2, chunk, max_live, budget);
    let tickets: Vec<_> = seqs.iter().map(|s| engine.submit(s.clone())).collect();
    let mut metrics = Metrics::default();
    let steps = engine.run_to_completion(&mut metrics);
    println!("\nphase-disaggregated ({budget}-token prefill budget): drained in {steps} steps");
    for (i, t) in tickets.iter().enumerate() {
        let out = engine.poll(t).expect("completed");
        assert_eq!(out.logits, solo_logits[i], "disaggregated scheduling must be bit-exact too");
        if i == 0 {
            println!(
                "  session 0: queue wait {:.2} ms, time-to-first-token {:.2} ms",
                out.queue_wait_ms(),
                out.ttft_ms()
            );
        }
    }
    let dec: f64 = metrics.decode_tokens.sum();
    let pre: f64 = metrics.prefill_tokens.sum();
    println!("bit-exactness under disaggregation ✓  ({dec:.0} decode + {pre:.0} prefill tokens)");
    Ok(())
}
